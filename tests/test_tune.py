"""Measured autotuning (ISSUE 6): harness, cache, tuner, calibration."""
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.compile import pipeline
from repro.core import dse, linalg, stt as stt_mod
from repro.core.algebra import batched_gemv, gemm
from repro.core.costmodel import PaperCycleModel
from repro.core.tiling import ArrayConfig
from repro.kernels import ops
from repro.tune import cache, calibrate, report, tuner
from repro.tune.measure import Measurement, measure

#: fast interpret-mode tuning knobs shared by the e2e tests
FAST = dict(interpret=True, repeats=2, warmup=1, validate=False)


def small_gemm():
    return gemm(16, 16, 16)


# ---------------------------------------------------------------------------
# Shared measurement harness
# ---------------------------------------------------------------------------

class TestMeasure:
    def test_counts_and_blocks(self):
        calls = []

        def fn(x):
            calls.append(x)
            return jnp.asarray([1.0])

        m = measure(fn, 7, warmup=2, repeats=5)
        assert len(calls) == 7          # 2 warmup + 5 timed
        assert len(m.times_s) == 5
        assert m.warmup_s >= 0.0
        assert all(t >= 0.0 for t in m.times_s)

    def test_statistics(self):
        m = Measurement(times_s=(3.0, 1.0, 2.0), warmup_s=0.1)
        assert m.median_s == 2.0
        assert m.best_s == 1.0
        assert m.mean_s == pytest.approx(2.0)
        m2 = Measurement(times_s=(1.0, 2.0, 3.0, 4.0), warmup_s=0.0)
        assert m2.median_s == 2.5
        assert m2.cycles(320.0) == pytest.approx(2.5 * 320e6)

    def test_repeats_validation(self):
        with pytest.raises(ValueError):
            measure(lambda: None, repeats=0)


# ---------------------------------------------------------------------------
# On-disk tuning cache
# ---------------------------------------------------------------------------

class TestTuneCache:
    def test_roundtrip_and_persistence(self):
        key = cache.key_of(("some", "compile", "key", 1))
        assert cache.lookup_variant(key) is None
        cache.store_variant(key, blocks=(8, 16, 32), grid_order="kmn",
                            accum="inplace", measured_s=0.5, untuned_s=1.0)
        entry = cache.lookup_variant(key)
        assert entry["blocks"] == [8, 16, 32]
        assert entry["grid_order"] == "kmn"
        assert entry["measured_s"] == 0.5
        # survives a memo reset (simulates a fresh process)
        cache.cache_clear(counters_only=True)
        assert cache.lookup_variant(key)["blocks"] == [8, 16, 32]

    def test_key_stability(self):
        # sha256 over repr: deterministic across processes, unlike hash()
        import hashlib
        tup = ("alg", ("m", "n"), 3.5)
        assert cache.key_of(tup) == hashlib.sha256(
            repr(tup).encode()).hexdigest()
        assert cache.key_of(tup) == cache.key_of(("alg", ("m", "n"), 3.5))
        assert cache.key_of(tup) != cache.key_of(("alg", ("m", "n"), 3.6))

    def test_corrupt_file_warns_and_falls_back(self):
        key = cache.key_of(("k",))
        cache.store_variant(key, blocks=(1, 1, 1), grid_order="default",
                            accum="auto")
        cache.cache_path().write_text("{ not json !!!")
        cache.cache_clear(counters_only=True)
        with pytest.warns(RuntimeWarning, match="unreadable"):
            assert cache.lookup_variant(key) is None
        assert cache.cache_info()["corrupt"] >= 1
        # the lower() consult path degrades to analytical, not an error
        k = pipeline.lower(small_gemm(), interpret=True, validate=False)
        assert k.source == "analytical"

    def test_version_mismatch_drops_entries(self):
        key = cache.key_of(("k2",))
        cache.store_variant(key, blocks=(2, 2, 2), grid_order="default",
                            accum="auto")
        doc = json.loads(cache.cache_path().read_text())
        doc["version"] = 999
        cache.cache_path().write_text(json.dumps(doc))
        cache.cache_clear(counters_only=True)
        assert cache.lookup_variant(key) is None
        assert cache.cache_info()["invalid"] >= 1

    def test_invalid_entry_rejected(self):
        key = cache.key_of(("k3",))
        cache.store_variant(key, blocks=(2, 2, 2), grid_order="default",
                            accum="auto")
        doc = json.loads(cache.cache_path().read_text())
        doc["variants"][key]["blocks"] = [0, -1]     # malformed
        cache.cache_path().write_text(json.dumps(doc))
        cache.cache_clear(counters_only=True)
        assert cache.lookup_variant(key) is None
        assert cache.cache_info()["invalid"] >= 1

    def test_counters(self):
        cache.cache_clear()
        key = cache.key_of(("k4",))
        assert cache.lookup_variant(key) is None
        cache.store_variant(key, blocks=(4, 4, 4), grid_order="default",
                            accum="auto")
        assert cache.lookup_variant(key) is not None
        info = cache.cache_info()
        assert info["hits"] == 1 and info["misses"] == 1
        assert info["stores"] == 1 and info["variants"] == 1

    def test_choice_roundtrip(self):
        key = cache.shape_key_for(small_gemm(), ArrayConfig(), jnp.float32,
                                  True, "pallas")
        variant = cache.store_variant(
            cache.key_of(("base",)), blocks=(16, 16, 16),
            grid_order="default", accum="auto")
        cache.store_choice(key, selected=("m", "n", "k"),
                           T=[[1, 0, 0], [0, 1, 0], [0, 0, 1]],
                           variant=variant, dataflow_name="MNK-X")
        got = cache.lookup_choice(key)
        assert got["selected"] == ["m", "n", "k"]
        assert got["variant"]["blocks"] == [16, 16, 16]


# ---------------------------------------------------------------------------
# Tuner end-to-end
# ---------------------------------------------------------------------------

class TestTuner:
    def test_tuned_never_slower_and_cache_hit(self):
        alg = small_gemm()
        res = tuner.tune(alg, search=1, **FAST)
        assert not res.cache_hit
        assert res.trials, "tuner must run trials on a cache miss"
        assert res.tuned_s <= res.untuned_s      # untuned is trial #0
        assert res.speedup >= 1.0
        assert res.kernel.source == "tuned"
        assert res.kernel.measured_s == res.tuned_s
        # second call: pure cache hit, no measurement
        res2 = tuner.tune(alg, search=1, **FAST)
        assert res2.cache_hit and res2.trials == ()
        assert res2.variant == res.variant
        assert res2.kernel.blocks == tuple(res.variant.blocks)

    def test_lower_consults_tuning_cache(self):
        alg = small_gemm()
        res = tuner.tune(alg, search=1, **FAST)
        pipeline.cache_clear()
        cache.cache_clear(counters_only=True)    # fresh memo, same file
        k = pipeline.lower(alg, res.dataflow, interpret=True,
                           validate=False)
        assert k.source == "tuned"
        assert k.blocks == tuple(res.variant.blocks)
        assert k.grid_order == res.variant.grid_order
        assert k.accum == res.variant.accum
        assert k.measured_s == pytest.approx(res.tuned_s)
        # tuned=False bypasses the consult
        k2 = pipeline.lower(alg, res.dataflow, interpret=True,
                            validate=False, tuned=False)
        assert k2.source == "analytical"

    def test_tuned_kernel_matches_oracle(self):
        alg = small_gemm()
        res = tuner.tune(alg, search=1, **FAST)
        assert res.kernel.validate() <= 1e-3

    def test_pinned_dataflow(self):
        alg = small_gemm()
        df = pipeline.default_dataflow(alg)
        res = tuner.tune(alg, df, force=True, **FAST)
        assert res.dataflow.signature == df.signature
        assert all(t.dataflow_name == df.name for t in res.trials)

    def test_measured_cycles_in_report(self):
        alg = small_gemm()
        res = tuner.tune(alg, search=1, **FAST)
        rep = res.kernel.cost_report()
        assert rep.measured_cycles == pytest.approx(
            res.tuned_s * ArrayConfig().freq_mhz * 1e6)

    def test_rank_measured_is_permutation(self):
        alg = batched_gemv(4, 16, 16)
        pairs = dse.search(alg, top_k=3)
        ranked = tuner.rank_measured(alg, pairs, **{
            k: v for k, v in FAST.items() if k != "validate"})
        assert len(ranked) == len(pairs)
        assert {id(df) for _, df, _ in ranked} == {id(df) for _, df in pairs}
        medians = [t for _, _, t in ranked]
        assert medians == sorted(medians)

    def test_generate_tune_front_door(self):
        import repro
        acc = repro.generate("gemm", bounds=dict(m=16, n=16, k=16),
                             tune=1, interpret=True, validate=False)
        assert acc.tune_result is not None
        assert not acc.tune_result.cache_hit
        assert "tuned:" in acc.describe()
        acc2 = repro.generate("gemm", bounds=dict(m=16, n=16, k=16),
                              tune=1, interpret=True, validate=False)
        assert acc2.tune_result.cache_hit
        with pytest.raises(ValueError):
            repro.generate("gemm", "output_stationary", tune=True)


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

class TestCalibration:
    def test_fit_scales(self):
        cal = calibrate.fit([
            {"template": "os", "algebra": "a",
             "model_cycles": 100.0, "measured_cycles": 200.0},
            {"template": "os", "algebra": "b",
             "model_cycles": 100.0, "measured_cycles": 800.0},
        ])
        assert cal.scale_for("os", "a") == pytest.approx(2.0)
        assert cal.scale_for("os", "b") == pytest.approx(8.0)
        # unseen algebra: per-template geomean fallback
        assert cal.scale_for("os", "zz") == pytest.approx(4.0)
        assert cal.scale_for("unknown") == 1.0

    def test_bad_records_skipped_and_scales_positive(self):
        cal = calibrate.fit([
            {"template": "t", "algebra": "a",
             "model_cycles": 0.0, "measured_cycles": 5.0},
            {"template": "t", "algebra": "a",
             "model_cycles": -3.0, "measured_cycles": 5.0},
            {"template": "t", "algebra": "a", "model_cycles": float("nan"),
             "measured_cycles": 5.0},
            {"template": "t"},                     # missing fields
        ])
        assert not cal                             # nothing usable
        assert cal.scale_for("t", "a") == 1.0
        # extreme ratios clamp to a positive band — never zero/negative
        ext = calibrate.fit([{"template": "t", "algebra": "a",
                              "model_cycles": 1e30,
                              "measured_cycles": 1e-30}])
        assert ext.scale_for("t", "a") > 0.0

    def test_calibrated_model_positive_and_flagged(self):
        alg = small_gemm()
        df = pipeline.default_dataflow(alg)
        cal = calibrate.Calibration(per_template={"output_stationary": 3.0})
        base = PaperCycleModel().evaluate(alg, df)
        rep = PaperCycleModel(calibration=cal).evaluate(alg, df)
        assert rep.calibrated and not base.calibrated
        assert rep.cycles == pytest.approx(3.0 * base.cycles)
        assert rep.cycles > 0
        # peak / normalized follow the calibrated cycles
        assert rep.normalized_perf == pytest.approx(
            rep.macs / rep.peak_macs)

    def test_calibration_requires_scale_for(self):
        with pytest.raises(TypeError):
            PaperCycleModel(calibration=object())

    def test_uniform_calibration_preserves_ranking(self):
        alg = batched_gemv(4, 16, 16)
        plain = dse.search(alg, top_k=0)
        templates = {p[0].dataflow_name for p in plain}  # noqa: F841
        cal = calibrate.Calibration(per_template={
            t: 2.5 for t in ("output_stationary", "operand_stationary",
                             "reduction_tree", "streaming")})
        scaled = dse.search(alg, top_k=0, calibration=cal)
        key = lambda p: (p[1].selected, p[1].signature)  # noqa: E731
        assert [key(p) for p in scaled] == [key(p) for p in plain]
        assert all(p[0].calibrated for p in scaled)

    def test_calibrated_search_is_permutation(self):
        alg = batched_gemv(4, 16, 16)
        plain = dse.search(alg, top_k=0)
        cal = calibrate.fit([
            {"template": "output_stationary", "algebra": alg.name,
             "model_cycles": 1.0, "measured_cycles": 250.0},
            {"template": "reduction_tree", "algebra": alg.name,
             "model_cycles": 1.0, "measured_cycles": 40.0},
        ])
        scaled = dse.search(alg, top_k=0, calibration=cal)
        key = lambda p: (p[1].selected, p[1].signature)  # noqa: E731
        assert sorted(map(key, scaled)) == sorted(map(key, plain))

    def test_record_persists_and_reloads(self):
        calibrate.record("output_stationary", "gemm", 1000.0, 250000.0)
        cal = calibrate.load()
        assert (cal.scale_for("output_stationary", "gemm") ==
            pytest.approx(250.0))
        # re-recording the same pair replaces, not dilutes
        calibrate.record("output_stationary", "gemm", 1000.0, 500000.0)
        assert calibrate.load().scale_for(
            "output_stationary", "gemm") == pytest.approx(500.0)

    def test_tune_records_calibration_within_2x(self):
        alg = small_gemm()
        res = tuner.tune(alg, search=1, **FAST)
        cal = calibrate.load()
        scale = cal.scale_for(res.kernel.template, alg.name)
        predicted = res.kernel.cost_report().cycles * scale
        measured = res.tuned_s * ArrayConfig().freq_mhz * 1e6
        assert 0.5 <= predicted / measured <= 2.0


# ---------------------------------------------------------------------------
# Kernel knobs (grid order / accumulation strategy)
# ---------------------------------------------------------------------------

class TestKnobs:
    @pytest.mark.parametrize("grid_order, accum", [
        ("default", "auto"), ("default", "inplace"),
        ("nmk", "auto"), ("nmk", "inplace"),
        # k-outer orders revisit the output block: inplace only
        ("kmn", "inplace"), ("knm", "inplace"),
    ])
    def test_os_variants_match(self, grid_order, accum):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-4, 5, (32, 24)), jnp.float32)
        b = jnp.asarray(rng.integers(-4, 5, (24, 16)), jnp.float32)
        got = ops.stt_matmul(a, b, template="output_stationary",
                             bm=8, bn=8, bk=8, interpret=True,
                             grid_order=grid_order, accum=accum)
        np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                                   rtol=1e-5)

    def test_scratch_rejects_k_outer(self):
        a = jnp.zeros((8, 8), jnp.float32)
        with pytest.raises(ValueError, match="scratch"):
            ops.stt_matmul(a, a, template="output_stationary",
                           bm=4, bn=4, bk=4, interpret=True,
                           grid_order="kmn", accum="scratch")

    def test_rt_grid_orders_match(self):
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.integers(-4, 5, (16, 16)), jnp.float32)
        b = jnp.asarray(rng.integers(-4, 5, (16, 16)), jnp.float32)
        for order in ("default", "nm", "nmk"):
            got = ops.stt_matmul(a, b, template="reduction_tree",
                                 bm=8, bn=8, interpret=True,
                                 grid_order=order)
            np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                                       rtol=1e-5)

    def test_resolve_accum(self):
        assert ops.resolve_accum("auto", jnp.float32) == "scratch"
        assert ops.resolve_accum("auto", jnp.bfloat16) == "scratch"
        assert ops.resolve_accum("inplace", jnp.float32) == "inplace"
        with pytest.raises(ValueError):
            ops.resolve_accum("bogus", jnp.float32)

    def test_variant_key_distinguishes_knobs(self):
        alg = small_gemm()
        df = pipeline.default_dataflow(alg)
        k1 = pipeline.lower(alg, df, interpret=True, validate=False,
                            tuned=False)
        k2 = pipeline.lower(alg, df, interpret=True, validate=False,
                            grid_order="kmn", accum="inplace")
        assert k1 is not k2
        assert k1.grid_order == "default" and k2.grid_order == "kmn"
        # same explicit knobs share one cache entry
        k3 = pipeline.lower(alg, df, interpret=True, validate=False,
                            grid_order="kmn", accum="inplace")
        assert k3 is k2


# ---------------------------------------------------------------------------
# BENCH_tune.json schema
# ---------------------------------------------------------------------------

def _valid_doc():
    cell = report.cell_entry(
        cell="tune_gemm", algebra="gemm", dataflow="MNK-MMT",
        template="output_stationary",
        variant={"blocks": (64, 64, 64), "grid_order": "kmn",
                 "accum": "inplace"},
        model_cycles=1024.0, calibrated_cycles=170000.0,
        measured_cycles=171000.0, untuned_s=1e-3, tuned_s=5e-4,
        tune_cache_hit=False)
    return {
        "version": report.BENCH_SCHEMA_VERSION,
        "smoke": True, "interpret": True, "cells": [cell],
        "calibration": {
            "per_template": {"output_stationary": 170.0},
            "anchors": [{"template": "output_stationary",
                         "algebra": "gemm", "scale": 170.0}],
        },
    }


class TestBenchSchema:
    def test_valid_doc_passes(self):
        assert report.validate_bench(_valid_doc()) == []

    @pytest.mark.parametrize("mutate, frag", [
        (lambda d: d.update(version=99), "version"),
        (lambda d: d.pop("smoke"), "smoke"),
        (lambda d: d.update(cells=[]), "cells"),
        (lambda d: d["cells"][0].pop("speedup"), "speedup"),
        (lambda d: d["cells"][0]["variant"].update(blocks=[0, 1]),
         "blocks"),
        (lambda d: d["calibration"]["per_template"].update(x=-1.0),
         "per_template"),
        (lambda d: d["calibration"]["anchors"].append({"bad": 1}),
         "anchors"),
    ])
    def test_mutations_rejected(self, mutate, frag):
        doc = _valid_doc()
        mutate(doc)
        errors = report.validate_bench(doc)
        assert errors and any(frag in e for e in errors), errors

    def test_speedup_computed(self):
        cell = _valid_doc()["cells"][0]
        assert cell["speedup"] == pytest.approx(2.0)
