"""Continuous-batching serving: paged cache, slot engine, async server.

The load-bearing claims, each tested directly:
  * the Pallas paged gather is bit-identical to its jnp twin;
  * the page pool's host accounting (alloc/free/oversubscription) is sound;
  * the slot engine reproduces sequential ``DecodeEngine.generate``
    token-for-token under staggered insert/evict, for every cache family
    (dense, SWA, SSM, hybrid) — with exactly ONE decode compilation;
  * the async server delivers the same bit-identical outputs to many
    submitting threads at once;
  * page placement flows through the partition solver.
"""
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.kernels.paged import (paged_gather, paged_gather_pallas,
                                 paged_scatter_token)
from repro.models import init_params, split
from repro.serve import (ContinuousServer, DecodeEngine, PagedKVCache,
                         ServeConfig, SlotEngine, solve_page_placement)
from repro.serve.slots import ResultTokens


def setup_arch(arch):
    cfg = get_config(arch).reduced()
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def make_prompts(cfg, reqs, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (s,)).astype(np.int32)
            for s, _ in reqs]


# ---------------------------------------------------------------------------
# paged gather/scatter kernel
# ---------------------------------------------------------------------------

def test_paged_gather_pallas_matches_jnp():
    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.standard_normal((9, 8, 32)).astype(np.float32))
    table = jnp.asarray(rng.integers(0, 9, (3, 4)).astype(np.int32))
    want = paged_gather(pool, table)
    got = paged_gather_pallas(pool, table, interpret=True)
    assert want.shape == (3, 32, 32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_scatter_token_writes_one_row():
    pool = jnp.zeros((4, 8, 16))
    vals = jnp.ones((2, 16))
    out = paged_scatter_token(pool, jnp.array([1, 3]), jnp.array([0, 7]),
                              vals)
    out = np.asarray(out)
    assert out[1, 0].sum() == 16 and out[3, 7].sum() == 16
    assert out.sum() == 32  # nothing else written


# ---------------------------------------------------------------------------
# page pool accounting
# ---------------------------------------------------------------------------

def _tiny_cache(capacity=4, page_size=8, seq=32, total_pages=None):
    template = {
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "self": {
            "k": jax.ShapeDtypeStruct((2, capacity, seq, 16), jnp.float32),
            "v": jax.ShapeDtypeStruct((2, capacity, seq, 16), jnp.float32)},
    }
    return PagedKVCache(template, capacity=capacity, page_size=page_size,
                        total_pages=total_pages)


def test_page_pool_alloc_free_roundtrip():
    cache = _tiny_cache(total_pages=8)     # 4 slots x 4 pages/slot max
    assert cache.free_pages == 8
    assert cache.alloc(0, 9)               # 9 positions -> 2 pages
    assert cache.free_pages == 6
    assert (cache.table[0] != cache.layout.scratch_page).sum() == 2
    cache.free(0)
    assert cache.free_pages == 8
    assert (cache.table[0] == cache.layout.scratch_page).all()


def test_page_pool_oversubscription_refused():
    cache = _tiny_cache(total_pages=5)
    assert cache.alloc(0, 32)              # 4 pages
    assert not cache.alloc(1, 32)          # would need 4, only 1 left
    assert cache.alloc(1, 8)               # 1 page still fits
    assert cache.free_pages == 0
    assert not cache.can_alloc(1)
    cache.free(0)
    assert cache.can_alloc(32)


def test_page_pool_double_alloc_refused():
    cache = _tiny_cache()
    assert cache.alloc(0, 8)
    assert not cache.alloc(0, 8)           # slot already holds pages


def test_shared_pool_long_and_short():
    """Long + short sequences draw from one pool: two full-context slots
    would not fit, but one long + two short do."""
    cache = _tiny_cache(total_pages=6)
    assert cache.alloc(0, 32)              # 4 pages (long)
    assert not cache.alloc(1, 32)
    assert cache.alloc(1, 8)               # 1 page (short)
    assert cache.alloc(2, 8)
    assert cache.free_pages == 0


# ---------------------------------------------------------------------------
# slot engine: bit-exact continuous decode
# ---------------------------------------------------------------------------

PARITY_ARCHS = ["granite-8b", "h2o-danube-1.8b", "mamba2-370m", "zamba2-1.2b"]
REQS = [(8, 6), (12, 4), (5, 8), (9, 3), (11, 6)]


def drive_continuous(eng, prompts, reqs):
    """Queue -> insert/step/evict until every request finished; returns
    per-request token lists."""
    got = {}
    queue = list(range(len(reqs)))
    resident, left = {}, {}
    while queue or resident:
        while queue and eng.free_slots():
            i = queue[0]
            res = eng.insert(prompts[i], max_new_tokens=reqs[i][1])
            if res is None:
                break
            queue.pop(0)
            slot, tok = res
            got[i] = [tok]
            if reqs[i][1] == 1:
                eng.evict(slot)
            else:
                resident[slot], left[slot] = i, reqs[i][1] - 1
        if not resident:
            continue
        r = eng.step()
        for slot, i in list(resident.items()):
            if not r.valid_at(slot):
                continue
            got[i].append(r.token_at(slot))
            left[slot] -= 1
            if left[slot] == 0:
                eng.evict(slot)
                del resident[slot], left[slot]
    return [np.asarray(got[i], np.int32) for i in range(len(reqs))]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_slot_engine_bit_parity(arch):
    cfg, params = setup_arch(arch)
    base = DecodeEngine(params, cfg)
    eng = SlotEngine(params, cfg, capacity=3, max_context=32, page_size=8)
    prompts = make_prompts(cfg, REQS)
    want = [base.generate(p[None], max_new_tokens=t, cache_len=32)[0][0]
            for p, (_, t) in zip(prompts, REQS)]
    got = drive_continuous(eng, prompts, REQS)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    # the continuous-batching contract: insert/evict never recompiled
    assert eng.decode_compiles == 1


def test_slot_engine_no_recompile_across_churn():
    cfg, params = setup_arch("granite-8b")
    eng = SlotEngine(params, cfg, capacity=2, max_context=16, page_size=8)
    p = np.arange(5, dtype=np.int32) % cfg.vocab
    for _ in range(3):                     # churn: insert/step/evict cycles
        slot, _ = eng.insert(p, max_new_tokens=2)
        eng.step()
        eng.evict(slot)
    assert eng.decode_compiles == 1
    assert eng.prefill_compiles == 1       # one prompt length -> one entry


def test_slot_engine_rejects_oversized_request():
    cfg, params = setup_arch("granite-8b")
    eng = SlotEngine(params, cfg, capacity=2, max_context=16, page_size=8)
    with pytest.raises(ValueError, match="max_context"):
        eng.insert(np.zeros((10,), np.int32), max_new_tokens=10)


def test_slot_engine_pool_exhaustion_returns_none():
    cfg, params = setup_arch("granite-8b")
    eng = SlotEngine(params, cfg, capacity=4, max_context=32, page_size=8,
                     total_pages=4)       # one full-length slot's worth
    p = np.arange(8, dtype=np.int32) % cfg.vocab
    assert eng.insert(p, max_new_tokens=24) is not None   # takes all 4
    assert eng.insert(p, max_new_tokens=8) is None        # pool exhausted
    eng.evict(0)
    assert eng.insert(p, max_new_tokens=8) is not None    # pages recycled


def test_result_tokens_packing():
    data = np.array([[7, 1, 12], [0, 0, 0]], np.int32)
    r = ResultTokens(data)
    assert r.token_at(0) == 7 and r.valid_at(0) and r.length_at(0) == 12
    assert not r.valid_at(1)


# ---------------------------------------------------------------------------
# async server
# ---------------------------------------------------------------------------

def test_server_multithreaded_submit_bit_parity():
    cfg, params = setup_arch("granite-8b")
    base = DecodeEngine(params, cfg)
    reqs = [(8, 6), (12, 4), (5, 8), (9, 3), (11, 6), (6, 5)]
    prompts = make_prompts(cfg, reqs)
    want = [base.generate(p[None], max_new_tokens=t, cache_len=32)[0][0]
            for p, (_, t) in zip(prompts, reqs)]

    eng = SlotEngine(params, cfg, capacity=3, max_context=32, page_size=8)
    futures = [None] * len(reqs)
    with ContinuousServer(eng, prefill_per_step=2) as server:
        def client(lo, hi):
            for i in range(lo, hi):
                futures[i] = server.submit(prompts[i],
                                           max_new_tokens=reqs[i][1])
        threads = [threading.Thread(target=client, args=(0, 3)),
                   threading.Thread(target=client, args=(3, 6))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        server.drain(timeout=300)
    for fut, w in zip(futures, want):
        np.testing.assert_array_equal(fut.result(timeout=5), w)
    assert eng.decode_compiles == 1
    assert server.stats["prefills"] == len(reqs)
    assert server.stats["evictions"] == len(reqs)


def test_server_eos_stops_request():
    """A request whose first decoded token is EOS finishes immediately
    with that single token (the slot never enters the decode batch)."""
    cfg, params = setup_arch("granite-8b")
    prompt = np.arange(7, dtype=np.int32) % cfg.vocab
    # learn what greedy emits first, then declare that token to be EOS
    probe = SlotEngine(params, cfg, capacity=2, max_context=16, page_size=8)
    _, first = probe.insert(prompt, max_new_tokens=4)

    eng = SlotEngine(params, cfg, capacity=2, max_context=16, page_size=8,
                     serve_cfg=ServeConfig(eos_id=int(first)))
    with ContinuousServer(eng) as server:
        fut = server.submit(prompt, max_new_tokens=4)
        out = fut.result(timeout=300)
    assert out.tolist() == [int(first)]
    assert not eng.live_slots()            # slot was evicted on EOS


def test_server_rejects_oversized_request_via_future():
    cfg, params = setup_arch("granite-8b")
    eng = SlotEngine(params, cfg, capacity=2, max_context=16, page_size=8)
    with ContinuousServer(eng) as server:
        fut = server.submit(np.zeros((12,), np.int32), max_new_tokens=12)
        with pytest.raises(ValueError, match="max_context"):
            fut.result(timeout=300)


# ---------------------------------------------------------------------------
# mesh placement of the page pools
# ---------------------------------------------------------------------------

def test_solve_page_placement_through_partition_solver():
    cfg, params = setup_arch("granite-8b")
    eng = SlotEngine(params, cfg, capacity=4, max_context=32, page_size=8)
    sol, spec = solve_page_placement(cfg, eng.cache.layout)
    assert isinstance(sol.strategy, str) and sol.strategy
    # pages shard over the batch-carrying mesh axis; page/feature axes
    # stay whole
    assert spec[0] in ("x", "y")
    assert len(spec) == 3 and spec[1] is None and spec[2] is None
