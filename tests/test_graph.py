"""Algebra graphs (PR 8): IR validation, planning, fusion, execution.

Covers the graph tentpole's contract surface:

* IR construction catches bad wiring (cycles, shape mismatches, unknown
  edges) at build time,
* a single-node graph degenerates bit-exactly to ``generate(alg)`` and
  shares its compile-cache entry,
* the attention+MLP chain is bit-identical to the explicit-schedule
  oracle with strictly fewer HBM bytes than the unfused pricing,
* non-fusable edges (B-side operand, dtype change) fall back to an HBM
  materialization with the cost charged,
* a diamond DAG executes its shared producer exactly once,
* the tuning cache never replays a standalone variant for a fused-group
  or epilogue'd lowering (the ``_cache_key`` regression).
"""
import numpy as np
import pytest

import repro
from repro.compile import pipeline
from repro.core.algebra import get_algebra
from repro.core.costmodel import GraphCostReport
from repro.core import dse
from repro.graph import AlgebraGraph, GraphNode, plan_graph
from repro.models import chains
from repro.tune import cache as tune_cache


def small_gemm(m=16, n=16, k=16):
    return get_algebra("gemm", m=m, n=n, k=k)


def single_node_graph():
    return AlgebraGraph(
        nodes=(GraphNode(name="mm", inputs=("A", "B"), output="C",
                         algebra=small_gemm()),),
        inputs=("A", "B"), output="C")


def chain_graph():
    """gemm -> gelu -> gemm, all fusable (the quickstart shape)."""
    return AlgebraGraph(
        nodes=(
            GraphNode(name="g1", inputs=("x", "W1"), output="h_raw",
                      algebra=small_gemm()),
            GraphNode(name="act", inputs=("h_raw",), output="h",
                      op="gelu"),
            GraphNode(name="g2", inputs=("h", "W2"), output="y",
                      algebra=small_gemm()),
        ),
        inputs=("x", "W1", "W2"), output="y")


# ---------------------------------------------------------------------------
# IR validation
# ---------------------------------------------------------------------------

class TestIR:
    def test_cycle_rejected(self):
        with pytest.raises(ValueError, match="cycle"):
            AlgebraGraph(
                nodes=(GraphNode(name="a", inputs=("y",), output="x",
                                 op="relu"),
                       GraphNode(name="b", inputs=("x",), output="y",
                                 op="relu")),
                inputs=(), output="y")

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError, match="shape mismatch"):
            AlgebraGraph(
                nodes=(GraphNode(name="g1", inputs=("x", "W"), output="h",
                                 algebra=small_gemm(16, 32, 16)),
                       GraphNode(name="g2", inputs=("h", "V"), output="y",
                                 algebra=small_gemm(16, 16, 16))),
                inputs=("x", "W", "V"), output="y")

    def test_unknown_edge_rejected(self):
        with pytest.raises(ValueError, match="unknown edge"):
            AlgebraGraph(
                nodes=(GraphNode(name="g", inputs=("x", "nope"),
                                 output="y", algebra=small_gemm()),),
                inputs=("x",), output="y")

    def test_duplicate_producer_rejected(self):
        with pytest.raises(ValueError, match="produced by both"):
            AlgebraGraph(
                nodes=(GraphNode(name="a", inputs=("x",), output="y",
                                 op="relu"),
                       GraphNode(name="b", inputs=("x",), output="y",
                                 op="tanh")),
                inputs=("x",), output="y")

    def test_epilogue_arity(self):
        with pytest.raises(ValueError, match="input edge"):
            GraphNode(name="b", inputs=("x",), output="y", op="bias")

    def test_reference_matches_manual(self):
        g = chain_graph()
        ops = g.random_operands(0)
        h = ops["x"].astype(np.float64) @ ops["W1"].T.astype(np.float64)
        from repro.kernels.epilogue import apply_epilogue_np
        want = apply_epilogue_np(h, ("gelu",)) @ ops["W2"].T
        got = g.reference(ops)
        np.testing.assert_allclose(got, want, atol=1e-9)


# ---------------------------------------------------------------------------
# Degeneration: one node == generate(alg)
# ---------------------------------------------------------------------------

class TestSingleNode:
    def test_bit_exact_and_cache_shared(self):
        g = single_node_graph()
        acc_g = repro.generate(g)
        acc_a = repro.generate(small_gemm())
        # the unconstrained node lowers with no fused_group/epilogue and
        # therefore shares the standalone compile-cache entry
        assert acc_g.kernels["mm"] is acc_a.kernel
        ops = g.random_operands(0)
        got = np.asarray(acc_g(ops))
        want = np.asarray(acc_a({"A": ops["A"], "B": ops["B"]}))
        assert (got == want).all()

    def test_cost_report_shape(self):
        rep = repro.generate(single_node_graph()).cost_report()
        assert isinstance(rep, GraphCostReport)
        assert rep.fused_edges == ()
        assert rep.hbm_bytes == rep.hbm_bytes_unfused  # nothing to fuse
        assert rep.cycles > 0


# ---------------------------------------------------------------------------
# Fusion: chain parity + honest byte accounting
# ---------------------------------------------------------------------------

class TestFusedChains:
    def test_gelu_chain_fuses_and_validates(self):
        g = chain_graph()
        acc = repro.generate(g)
        p = acc.plan.nodes["g1"]
        assert p.epilogue == ("gelu",) and p.epilogue_fused
        rep = acc.cost_report()
        assert len(rep.fused_edges) == 1
        assert rep.hbm_bytes < rep.hbm_bytes_unfused
        acc.validate(seed=0)

    def test_attention_mlp_bit_parity(self):
        g = chains.attention_mlp_graph(lq=32, lkv=32, d=32, dv=32, f=64)
        acc = repro.generate(g)
        ops = g.random_operands(1)
        got = np.asarray(acc(ops))
        want = np.asarray(chains.attention_mlp_oracle(
            {k: v for k, v in ops.items()}))
        assert got.shape == want.shape
        assert (got == want).all(), (
            f"max err {np.abs(got - want).max():.3e}")

    def test_attention_mlp_fewer_hbm_bytes(self):
        g = chains.attention_mlp_graph(lq=32, lkv=32, d=32, dv=32, f=64)
        rep = repro.generate(g).cost_report()
        assert len(rep.fused_edges) == 3     # probs, attn, mlp_h
        assert rep.hbm_bytes < rep.hbm_bytes_unfused
        assert rep.saved_hbm_bytes > 0
        assert rep.hbm_ratio > 1.3
        # the softmax/gelu epilogues are folded into the gemm kernels
        plan = repro.generate(g).plan
        assert (plan.nodes["scores"].epilogue ==
            (chains._scale_op(32), "softmax"))
        assert plan.nodes["mlp_up"].epilogue == ("bias", "gelu")

    def test_search_graph_returns_plan(self):
        g = chain_graph()
        plan = dse.search_graph(g, search=2)
        assert set(plan.nodes) == {"g1", "g2"}
        rep = plan.cost_report()
        assert rep.cycles > 0 and rep.hbm_bytes <= rep.hbm_bytes_unfused


# ---------------------------------------------------------------------------
# Non-fusable edges fall back to materialization, cost charged
# ---------------------------------------------------------------------------

class TestMaterialization:
    def b_side_graph(self):
        """g2 consumes g1's output as its *B* operand (stored
        transposed by gemm's prepare) — never fusable."""
        return AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h",
                          algebra=small_gemm()),
                GraphNode(name="g2", inputs=("y2", "h"), output="z",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W1", "y2"), output="z")

    def test_b_side_edge_materializes(self):
        g = self.b_side_graph()
        acc = repro.generate(g)
        rep = acc.cost_report()
        assert rep.fused_edges == ()
        mats = dict(rep.materialized_edges)
        assert any("transposed" in why for why in mats.values())
        # the materialized edge is charged: write + read of 16x16 fp32
        assert rep.edge_bytes["h"] == 2 * 16 * 16 * 4
        acc.validate(seed=0)

    def test_dtype_change_blocks_fusion(self):
        g = AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h",
                          algebra=small_gemm()),
                GraphNode(name="g2", inputs=("h", "W2"), output="y",
                          algebra=small_gemm(), dtype="bfloat16"),
            ),
            inputs=("x", "W1", "W2"), output="y")
        plan = plan_graph(g)
        edge = next(e for e in plan.edges if e.producer == "g1")
        assert not edge.fused and "dtype" in edge.reason
        rep = plan.cost_report()
        assert rep.fused_edges == ()

    def test_fanout_blocks_epilogue_folding(self):
        # h_raw has two consumers: the epilogue cannot fold into g1
        g = AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h_raw",
                          algebra=small_gemm()),
                GraphNode(name="act", inputs=("h_raw",), output="h",
                          op="relu"),
                GraphNode(name="g2", inputs=("h", "W2"), output="y1",
                          algebra=small_gemm()),
                GraphNode(name="g3", inputs=("h_raw", "W3"), output="y2",
                          algebra=small_gemm()),
                GraphNode(name="last", inputs=("y1", "y2"), output="z",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W1", "W2", "W3"), output="z")
        acc = repro.generate(g)
        assert acc.plan.nodes["g1"].epilogue == ()
        # the standalone relu node pays its round trip in the pricing
        assert acc.cost_report().edge_bytes["h"] > 0
        acc.validate(seed=1)


# ---------------------------------------------------------------------------
# Diamond DAG: shared producer executes once
# ---------------------------------------------------------------------------

class TestDiamond:
    def diamond(self):
        return AlgebraGraph(
            nodes=(
                GraphNode(name="p", inputs=("x", "W"), output="c",
                          algebra=small_gemm()),
                GraphNode(name="q1", inputs=("c", "W1"), output="o1",
                          algebra=small_gemm()),
                GraphNode(name="q2", inputs=("c", "W2"), output="o2",
                          algebra=small_gemm()),
                GraphNode(name="r", inputs=("o1", "o2"), output="z",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W", "W1", "W2"), output="z")

    def test_producer_runs_once(self, monkeypatch):
        g = self.diamond()
        acc = repro.generate(g)       # lower (and validate) first
        calls = []
        orig = pipeline.CompiledKernel.__call__

        def counting(self, operands):
            calls.append(self.algebra.name)
            return orig(self, operands)

        monkeypatch.setattr(pipeline.CompiledKernel, "__call__", counting)
        ops = g.random_operands(0)
        got = np.asarray(acc(ops))
        assert len(calls) == 4        # p, q1, q2, r — p not re-computed
        np.testing.assert_allclose(
            got, g.reference(ops).astype(np.float64), atol=1e-3)

    def test_fanout_edge_priced_per_consumer(self):
        rep = plan_graph(self.diamond()).cost_report()
        # c fans out to two consumers: at most one write + unfused reads
        # are charged; both q-edges into r can never both fuse (B side)
        assert rep.hbm_bytes <= rep.hbm_bytes_unfused


# ---------------------------------------------------------------------------
# Tuning-cache keys: fused-group / epilogue never alias standalone
# ---------------------------------------------------------------------------

class TestTuneCacheKeys:
    def test_fused_group_not_served_standalone_variant(self):
        alg = small_gemm()
        df = pipeline.default_dataflow(alg)
        base = pipeline._cache_key(alg, df, pipeline.ArrayConfig(),
                                   "float32", True, "pallas")
        tune_cache.store_variant(tune_cache.key_of(base),
                                 blocks=(8, 8, 8), grid_order="mnk",
                                 accum="scratch")
        pipeline.cache_clear()
        plain = pipeline.lower(alg, df, interpret=True)
        assert plain.source == "tuned" and plain.blocks == (8, 8, 8)
        fused = pipeline.lower(alg, df, interpret=True,
                               fused_group="g:test")
        assert fused.source == "analytical" and fused.blocks != (8, 8, 8)
        epi = pipeline.lower(alg, df, interpret=True, epilogue=("relu",))
        assert epi.source == "analytical"

    def test_variant_stored_for_fused_group_is_found(self):
        alg = small_gemm()
        df = pipeline.default_dataflow(alg)
        key = pipeline._cache_key(alg, df, pipeline.ArrayConfig(),
                                  "float32", True, "pallas",
                                  fused_group="g:test")
        tune_cache.store_variant(tune_cache.key_of(key),
                                 blocks=(4, 4, 4), grid_order="kmn",
                                 accum="inplace")
        pipeline.cache_clear()
        fused = pipeline.lower(alg, df, interpret=True,
                               fused_group="g:test")
        assert fused.source == "tuned" and fused.blocks == (4, 4, 4)
