"""Algebra graphs (PR 8): IR validation, planning, fusion, execution.

Covers the graph tentpole's contract surface:

* IR construction catches bad wiring (cycles, shape mismatches, unknown
  edges) at build time,
* a single-node graph degenerates bit-exactly to ``generate(alg)`` and
  shares its compile-cache entry,
* the attention+MLP chain is bit-identical to the explicit-schedule
  oracle with strictly fewer HBM bytes than the unfused pricing,
* non-fusable edges (B-side operand, dtype change) fall back to an HBM
  materialization with the cost charged,
* a diamond DAG executes its shared producer exactly once,
* the tuning cache never replays a standalone variant for a fused-group
  or epilogue'd lowering (the ``_cache_key`` regression).
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.compile import pipeline
from repro.core.algebra import get_algebra
from repro.core.costmodel import GraphCostReport
from repro.core import dse
from repro.graph import AlgebraGraph, GraphNode, plan_graph
from repro.graph import executor as graph_executor
from repro.kernels import fused_chain
from repro.models import chains
from repro.tune import cache as tune_cache


def small_gemm(m=16, n=16, k=16):
    return get_algebra("gemm", m=m, n=n, k=k)


def single_node_graph():
    return AlgebraGraph(
        nodes=(GraphNode(name="mm", inputs=("A", "B"), output="C",
                         algebra=small_gemm()),),
        inputs=("A", "B"), output="C")


def chain_graph():
    """gemm -> gelu -> gemm, all fusable (the quickstart shape)."""
    return AlgebraGraph(
        nodes=(
            GraphNode(name="g1", inputs=("x", "W1"), output="h_raw",
                      algebra=small_gemm()),
            GraphNode(name="act", inputs=("h_raw",), output="h",
                      op="gelu"),
            GraphNode(name="g2", inputs=("h", "W2"), output="y",
                      algebra=small_gemm()),
        ),
        inputs=("x", "W1", "W2"), output="y")


# ---------------------------------------------------------------------------
# IR validation
# ---------------------------------------------------------------------------

class TestIR:
    def test_cycle_rejected(self):
        with pytest.raises(ValueError, match="cycle"):
            AlgebraGraph(
                nodes=(GraphNode(name="a", inputs=("y",), output="x",
                                 op="relu"),
                       GraphNode(name="b", inputs=("x",), output="y",
                                 op="relu")),
                inputs=(), output="y")

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError, match="shape mismatch"):
            AlgebraGraph(
                nodes=(GraphNode(name="g1", inputs=("x", "W"), output="h",
                                 algebra=small_gemm(16, 32, 16)),
                       GraphNode(name="g2", inputs=("h", "V"), output="y",
                                 algebra=small_gemm(16, 16, 16))),
                inputs=("x", "W", "V"), output="y")

    def test_unknown_edge_rejected(self):
        with pytest.raises(ValueError, match="unknown edge"):
            AlgebraGraph(
                nodes=(GraphNode(name="g", inputs=("x", "nope"),
                                 output="y", algebra=small_gemm()),),
                inputs=("x",), output="y")

    def test_duplicate_producer_rejected(self):
        with pytest.raises(ValueError, match="produced by both"):
            AlgebraGraph(
                nodes=(GraphNode(name="a", inputs=("x",), output="y",
                                 op="relu"),
                       GraphNode(name="b", inputs=("x",), output="y",
                                 op="tanh")),
                inputs=("x",), output="y")

    def test_epilogue_arity(self):
        with pytest.raises(ValueError, match="input edge"):
            GraphNode(name="b", inputs=("x",), output="y", op="bias")

    def test_reference_matches_manual(self):
        g = chain_graph()
        ops = g.random_operands(0)
        h = ops["x"].astype(np.float64) @ ops["W1"].T.astype(np.float64)
        from repro.kernels.epilogue import apply_epilogue_np
        want = apply_epilogue_np(h, ("gelu",)) @ ops["W2"].T
        got = g.reference(ops)
        np.testing.assert_allclose(got, want, atol=1e-9)


# ---------------------------------------------------------------------------
# Degeneration: one node == generate(alg)
# ---------------------------------------------------------------------------

class TestSingleNode:
    def test_bit_exact_and_cache_shared(self):
        g = single_node_graph()
        acc_g = repro.generate(g)
        acc_a = repro.generate(small_gemm())
        # the unconstrained node lowers with no fused_group/epilogue and
        # therefore shares the standalone compile-cache entry
        assert acc_g.kernels["mm"] is acc_a.kernel
        ops = g.random_operands(0)
        got = np.asarray(acc_g(ops))
        want = np.asarray(acc_a({"A": ops["A"], "B": ops["B"]}))
        assert (got == want).all()

    def test_cost_report_shape(self):
        rep = repro.generate(single_node_graph()).cost_report()
        assert isinstance(rep, GraphCostReport)
        assert rep.fused_edges == ()
        assert rep.hbm_bytes == rep.hbm_bytes_unfused  # nothing to fuse
        assert rep.cycles > 0


# ---------------------------------------------------------------------------
# Fusion: chain parity + honest byte accounting
# ---------------------------------------------------------------------------

class TestFusedChains:
    def test_gelu_chain_fuses_and_validates(self):
        g = chain_graph()
        acc = repro.generate(g)
        p = acc.plan.nodes["g1"]
        assert p.epilogue == ("gelu",) and p.epilogue_fused
        rep = acc.cost_report()
        assert len(rep.fused_edges) == 1
        assert rep.hbm_bytes < rep.hbm_bytes_unfused
        acc.validate(seed=0)

    def test_attention_mlp_bit_parity(self):
        g = chains.attention_mlp_graph(lq=32, lkv=32, d=32, dv=32, f=64)
        acc = repro.generate(g)
        ops = g.random_operands(1)
        got = np.asarray(acc(ops))
        want = np.asarray(chains.attention_mlp_oracle(
            {k: v for k, v in ops.items()}))
        assert got.shape == want.shape
        assert (got == want).all(), (
            f"max err {np.abs(got - want).max():.3e}")

    def test_attention_mlp_fewer_hbm_bytes(self):
        g = chains.attention_mlp_graph(lq=32, lkv=32, d=32, dv=32, f=64)
        rep = repro.generate(g).cost_report()
        assert len(rep.fused_edges) == 3     # probs, attn, mlp_h
        assert rep.hbm_bytes < rep.hbm_bytes_unfused
        assert rep.saved_hbm_bytes > 0
        assert rep.hbm_ratio > 1.3
        # the softmax/gelu epilogues are folded into the gemm kernels
        plan = repro.generate(g).plan
        assert (plan.nodes["scores"].epilogue ==
            (chains._scale_op(32), "softmax"))
        assert plan.nodes["mlp_up"].epilogue == ("bias", "gelu")

    def test_search_graph_returns_plan(self):
        g = chain_graph()
        plan = dse.search_graph(g, search=2)
        assert set(plan.nodes) == {"g1", "g2"}
        rep = plan.cost_report()
        assert rep.cycles > 0 and rep.hbm_bytes <= rep.hbm_bytes_unfused


# ---------------------------------------------------------------------------
# Non-fusable edges fall back to materialization, cost charged
# ---------------------------------------------------------------------------

class TestMaterialization:
    def b_side_graph(self):
        """g2 consumes g1's output as its *B* operand: the edge arrives
        in B's (n, k) storage layout, so the merged DAG kernel reads the
        producer's scratch transposed — no materialized transpose."""
        return AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h",
                          algebra=small_gemm()),
                GraphNode(name="g2", inputs=("y2", "h"), output="z",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W1", "y2"), output="z")

    def test_b_side_edge_fuses_on_rhs(self):
        g = self.b_side_graph()
        acc = repro.generate(g)
        edge = next(e for e in acc.plan.edges if e.producer == "g1")
        assert edge.fused and edge.side == "rhs"
        rep = acc.cost_report()
        assert "g1->g2:h" in rep.fused_edges
        # no "stores transposed" fallback anywhere, nothing charged for h
        assert not any("transposed" in why
                       for _, why in rep.materialized_edges)
        assert rep.edge_bytes.get("h", 0.0) == 0.0
        (grp,) = acc.plan.groups
        assert grp.kind == "dag" and grp.eligible
        assert list(acc.group_kernels) == [grp.name]
        acc.validate(seed=0)
        # bit-identical to sequential dispatch of the same plan
        ops = g.random_operands(0)
        seq = graph_executor.build(g, interpret=True, merge=False)
        np.testing.assert_array_equal(np.asarray(acc(ops)),
                                      np.asarray(seq(ops)))

    def test_dtype_change_blocks_fusion(self):
        g = AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h",
                          algebra=small_gemm()),
                GraphNode(name="g2", inputs=("h", "W2"), output="y",
                          algebra=small_gemm(), dtype="bfloat16"),
            ),
            inputs=("x", "W1", "W2"), output="y")
        plan = plan_graph(g)
        edge = next(e for e in plan.edges if e.producer == "g1")
        assert not edge.fused and "dtype" in edge.reason
        rep = plan.cost_report()
        assert rep.fused_edges == ()

    def test_fanout_blocks_epilogue_folding(self):
        # h_raw has two consumers: the epilogue cannot fold into g1
        g = AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h_raw",
                          algebra=small_gemm()),
                GraphNode(name="act", inputs=("h_raw",), output="h",
                          op="relu"),
                GraphNode(name="g2", inputs=("h", "W2"), output="y1",
                          algebra=small_gemm()),
                GraphNode(name="g3", inputs=("h_raw", "W3"), output="y2",
                          algebra=small_gemm()),
                GraphNode(name="last", inputs=("y1", "y2"), output="z",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W1", "W2", "W3"), output="z")
        acc = repro.generate(g)
        assert acc.plan.nodes["g1"].epilogue == ()
        # the standalone relu node pays its round trip in the pricing
        assert acc.cost_report().edge_bytes["h"] > 0
        acc.validate(seed=1)


# ---------------------------------------------------------------------------
# Diamond DAG: shared producer executes once
# ---------------------------------------------------------------------------

class TestDiamond:
    def diamond(self):
        return AlgebraGraph(
            nodes=(
                GraphNode(name="p", inputs=("x", "W"), output="c",
                          algebra=small_gemm()),
                GraphNode(name="q1", inputs=("c", "W1"), output="o1",
                          algebra=small_gemm()),
                GraphNode(name="q2", inputs=("c", "W2"), output="o2",
                          algebra=small_gemm()),
                GraphNode(name="r", inputs=("o1", "o2"), output="z",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W", "W1", "W2"), output="z")

    def test_producer_runs_once(self, monkeypatch):
        # merge=False: the PR 8 sequential path — one dispatch per node
        g = self.diamond()
        acc = graph_executor.build(g, interpret=True, merge=False)
        calls = []
        orig = pipeline.CompiledKernel.__call__

        def counting(self, operands):
            calls.append(self.algebra.name)
            return orig(self, operands)

        monkeypatch.setattr(pipeline.CompiledKernel, "__call__", counting)
        ops = g.random_operands(0)
        got = np.asarray(acc(ops))
        assert len(calls) == 4        # p, q1, q2, r — p not re-computed
        np.testing.assert_allclose(
            got, g.reference(ops).astype(np.float64), atol=1e-3)

    def test_producer_runs_once_merged(self, monkeypatch):
        # default path: the whole diamond merges into ONE dag megakernel
        # (q2->r lands on r's rhs; the shared c strip feeds q1 AND q2
        # from scratch) — zero per-node dispatches, one pallas_call
        g = self.diamond()
        acc = repro.generate(g)
        assert list(acc.group_kernels) == ["mg:p+q1+q2+r"]
        assert acc.plan.groups[0].kind == "dag"
        calls, group_calls = [], []
        orig = pipeline.CompiledKernel.__call__
        gorig = pipeline.CompiledGroupKernel.__call__

        def counting(self, operands):
            calls.append(self.algebra.name)
            return orig(self, operands)

        def gcounting(self, lhs, rhss=(), biases=()):
            group_calls.append(self.group)
            return gorig(self, lhs, rhss, biases)

        monkeypatch.setattr(pipeline.CompiledKernel, "__call__", counting)
        monkeypatch.setattr(pipeline.CompiledGroupKernel, "__call__",
                            gcounting)
        ops = g.random_operands(0)
        got = np.asarray(acc(ops))
        assert calls == []            # everything ran inside the group
        # one megakernel dispatch (its .group label may name another
        # graph's structurally-identical chain — entries are shared)
        assert len(group_calls) == 1
        np.testing.assert_allclose(
            got, g.reference(ops).astype(np.float64), atol=1e-3)

    def test_fanout_edge_priced_per_consumer(self):
        rep = plan_graph(self.diamond()).cost_report()
        # every diamond edge fuses (c feeds both consumers from the
        # merged group's scratch); the model can only save bytes
        assert rep.hbm_bytes <= rep.hbm_bytes_unfused


# ---------------------------------------------------------------------------
# Tuning-cache keys: fused-group / epilogue never alias standalone
# ---------------------------------------------------------------------------

class TestTuneCacheKeys:
    def test_fused_group_not_served_standalone_variant(self):
        alg = small_gemm()
        df = pipeline.default_dataflow(alg)
        base = pipeline._cache_key(alg, df, pipeline.ArrayConfig(),
                                   "float32", True, "pallas")
        tune_cache.store_variant(tune_cache.key_of(base),
                                 blocks=(8, 8, 8), grid_order="mnk",
                                 accum="scratch")
        pipeline.cache_clear()
        plain = pipeline.lower(alg, df, interpret=True)
        assert plain.source == "tuned" and plain.blocks == (8, 8, 8)
        fused = pipeline.lower(alg, df, interpret=True,
                               fused_group="g:test")
        assert fused.source == "analytical" and fused.blocks != (8, 8, 8)
        epi = pipeline.lower(alg, df, interpret=True, epilogue=("relu",))
        assert epi.source == "analytical"


# ---------------------------------------------------------------------------
# Merged-kernel execution (ISSUE 9): one pallas_call per fused chain
# ---------------------------------------------------------------------------

def group_operands(group, ops):
    """The group's external operands picked out of a graph operand dict."""
    return (ops[group.lhs_edge],
            [ops[e] for e in group.rhs_edges],
            [ops[e] for e in group.bias_edges if e is not None])


class TestMergedKernel:
    def test_merged_single_pallas_call(self, monkeypatch):
        # the acceptance chain: gemm·gelu·gemm runs as ONE megakernel —
        # zero per-node dispatches — and is bit-exact vs the sequential
        # path (bm == m: identical dot + epilogue sequence)
        g = chain_graph()
        acc = repro.generate(g)
        assert list(acc.group_kernels) == ["mg:g1+g2"]
        seq = graph_executor.build(g, interpret=True, merge=False)
        ops = g.random_operands(0)
        want_seq = np.asarray(seq(ops))

        calls, group_calls = [], []
        orig = pipeline.CompiledKernel.__call__
        gorig = pipeline.CompiledGroupKernel.__call__
        monkeypatch.setattr(
            pipeline.CompiledKernel, "__call__",
            lambda self, operands: calls.append(self.algebra.name)
            or orig(self, operands))
        monkeypatch.setattr(
            pipeline.CompiledGroupKernel, "__call__",
            lambda self, lhs, rhss, biases=():
            group_calls.append(self.group) or gorig(self, lhs, rhss, biases))
        got = np.asarray(acc(ops))
        assert calls == []                 # nothing dispatched per-node
        assert len(group_calls) == 1       # the whole chain: ONE pallas_call
        np.testing.assert_array_equal(got, want_seq)      # bit-exact
        np.testing.assert_allclose(
            got, g.reference(ops).astype(np.float64), atol=1e-3)

    def test_merged_attention_mlp_parity(self):
        # the scores->softmax->attend pair + MLP merges into one chain,
        # still matching the numpy graph oracle and the sequential path
        g = chains.attention_mlp_graph(lq=32, lkv=32, d=32, dv=32, f=64)
        acc = repro.generate(g)
        assert list(acc.group_kernels) == ["mg:scores+attend+mlp_up+mlp_down"]
        gk = acc.group_kernels["mg:scores+attend+mlp_up+mlp_down"]
        assert gk.bm == gk.m              # whole-tensor degenerate phase
        seq = graph_executor.build(g, interpret=True, merge=False)
        ops = g.random_operands(0)
        np.testing.assert_array_equal(np.asarray(acc(ops)),
                                      np.asarray(seq(ops)))
        acc.validate()

    def test_merged_nondivisible_m_blocks(self):
        # m=24 against bm in {7, 16}: the pad-to-multiple + slice path,
        # on both stage interleaves
        g = AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h_raw",
                          algebra=small_gemm(m=24, n=32, k=16)),
                GraphNode(name="act", inputs=("h_raw",), output="h",
                          op="gelu"),
                GraphNode(name="g2", inputs=("h", "W2"), output="y",
                          algebra=small_gemm(m=24, n=16, k=32)),
            ),
            inputs=("x", "W1", "W2"), output="y")
        plan = plan_graph(g)
        grp = next(x for x in plan.groups if x.eligible)
        ops = g.random_operands(0)
        want = np.asarray(g.reference(ops), np.float64)
        bound = 1e-3 + 1e-5 * np.abs(want).max()
        lhs, rhss, biases = group_operands(grp, ops)
        for bm in (7, 16):
            for il in fused_chain.FUSED_INTERLEAVES:
                gk = pipeline.lower_group(plan, grp, interpret=True,
                                          bm=bm, interleave=il)
                got = np.asarray(gk(lhs, rhss, biases), np.float64)
                assert got.shape == want.shape
                assert np.abs(got - want).max() <= bound, (bm, il)

    def test_merged_bf16_chain(self):
        # validate=False: the per-node lower-time oracle check uses an
        # fp32 atol; the bf16-tolerance oracle comparison happens below
        g = chain_graph()
        acc = graph_executor.build(g, interpret=True, dtype=jnp.bfloat16,
                                   validate=False)
        assert list(acc.group_kernels) == ["mg:g1+g2"]
        seq = graph_executor.build(g, interpret=True, dtype=jnp.bfloat16,
                                   merge=False, validate=False)
        ops = g.random_operands(0)
        got = np.asarray(acc(ops), np.float64)
        # same per-stage math (fp32 dot, fp32 epilogue, bf16 cast between
        # stages) in the same order: bit-equal to sequential dispatch
        np.testing.assert_array_equal(got, np.asarray(seq(ops), np.float64))
        want = np.asarray(g.reference(ops), np.float64)
        scale = np.abs(want).max() + 1e-30
        assert np.abs(got - want).max() / scale <= 2e-2

    def test_merged_vmem_overflow_falls_back(self):
        # a budget too small for the intermediate strip: the planner
        # keeps the group as documentation (eligible=False) and the
        # executor stays sequential — still matching the oracle
        from repro.core.tiling import ArrayConfig
        g = chain_graph()
        cfg = ArrayConfig(vmem_budget_bytes=2048)
        plan = plan_graph(g, cfg=cfg)
        assert plan.groups and not plan.groups[0].eligible
        assert "VMEM" in plan.groups[0].reason
        acc = graph_executor.build(g, plan=plan, interpret=True, cfg=cfg)
        assert not acc.group_kernels
        acc.validate()

    def test_merged_sequential_verdict_respected(self):
        # a persisted merged=False verdict (sequential measured faster)
        # makes lower_group decline and build() keep per-node dispatch
        g = chain_graph()
        plan = plan_graph(g)
        grp = next(x for x in plan.groups if x.eligible)
        digest = tune_cache.key_of(
            pipeline._group_cache_key(plan, grp, True, "pallas"))
        tune_cache.store_group(digest, merged=False)
        assert pipeline.lower_group(plan, grp, interpret=True) is None
        acc = graph_executor.build(g, plan=plan, interpret=True)
        assert not acc.group_kernels
        acc.validate()

    def test_merged_tune_group_verdict_cached(self):
        from repro.tune import tuner
        g = chain_graph()
        plan = plan_graph(g)
        grp = next(x for x in plan.groups if x.eligible)
        res = tuner.tune_group(plan, grp, interpret=True,
                               repeats=1, warmup=0)
        assert not res.cache_hit and res.trials
        assert all(t.ok for t in res.trials)
        res2 = tuner.tune_group(plan, grp, interpret=True)
        assert res2.cache_hit and res2.merged == res.merged
        # and build(tune=...) consumes the same verdict without measuring
        acc = graph_executor.build(g, plan=plan, interpret=True, tune=8)
        assert acc.group_tuning[grp.name].cache_hit
        assert bool(acc.group_kernels) == res.merged
        acc.validate()

    def test_merged_bias_key_collision_rejected(self):
        # regression (ISSUE 9 bugfix): a tensor name inside the reserved
        # "bias:" operand namespace would silently shadow the injected
        # bias vector; build() must reject it
        g = AlgebraGraph(
            nodes=(GraphNode(name="mm", inputs=("bias:x", "B"),
                             output="C", algebra=small_gemm()),),
            inputs=("bias:x", "B"), output="C")
        with pytest.raises(ValueError, match="bias:"):
            graph_executor.build(g, interpret=True)

    def test_merged_group_cache_key_separates_epilogues(self):
        # two chains identical but for one stage's folded epilogue must
        # not share a merged compile/tune cache entry
        g1 = chain_graph()
        g2 = AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h_raw",
                          algebra=small_gemm()),
                GraphNode(name="act", inputs=("h_raw",), output="h",
                          op="relu"),
                GraphNode(name="g2", inputs=("h", "W2"), output="y",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W1", "W2"), output="y")
        p1, p2 = plan_graph(g1), plan_graph(g2)
        k1 = pipeline._group_cache_key(p1, p1.groups[0], True, "pallas")
        k2 = pipeline._group_cache_key(p2, p2.groups[0], True, "pallas")
        assert k1 != k2

    def test_variant_stored_for_fused_group_is_found(self):
        alg = small_gemm()
        df = pipeline.default_dataflow(alg)
        key = pipeline._cache_key(alg, df, pipeline.ArrayConfig(),
                                  "float32", True, "pallas",
                                  fused_group="g:test")
        tune_cache.store_variant(tune_cache.key_of(key),
                                 blocks=(4, 4, 4), grid_order="kmn",
                                 accum="inplace")
        pipeline.cache_clear()
        fused = pipeline.lower(alg, df, interpret=True,
                               fused_group="g:test")
        assert fused.source == "tuned" and fused.blocks == (4, 4, 4)


# ---------------------------------------------------------------------------
# Multi-output taps (ISSUE 10): merged groups exporting intermediates
# ---------------------------------------------------------------------------

def tap_diamond_graph(m=16, n=16, k=16):
    """p -> t read by an in-group lhs consumer AND an out-of-group
    residual add: the merged group must export ``t`` as a tap."""
    return AlgebraGraph(
        nodes=(
            GraphNode(name="p", inputs=("x", "w0"), output="t",
                      algebra=get_algebra("gemm", m=m, n=n, k=k)),
            GraphNode(name="c1", inputs=("t", "w1"), output="y1",
                      algebra=get_algebra("gemm", m=m, n=n, k=n)),
            GraphNode(name="fin", inputs=("y1", "t"), output="out",
                      op="add"),
        ),
        inputs=("x", "w0", "w1"), output="out")


class TestTaps:
    def test_tap_exported_for_residual_add(self):
        g = tap_diamond_graph()
        plan = plan_graph(g)
        grp = next(x for x in plan.groups if x.eligible)
        assert grp.kind == "dag" and grp.taps == (("p", "t"),)
        rep = plan.cost_report()
        assert any(t.endswith(":t") for t in rep.tapped_edges)
        assert rep.tap_hbm_bytes > 0
        acc = graph_executor.build(g, plan=plan, interpret=True)
        assert acc.group_kernels[grp.name].n_tap == 1
        acc.validate()
        ops = g.random_operands(0)
        seq = graph_executor.build(g, interpret=True, merge=False)
        assert np.array_equal(np.asarray(acc(ops)), np.asarray(seq(ops)))

    def test_tap_nondivisible_m(self):
        # whole-tensor dag phases don't need m % pe == 0
        g = tap_diamond_graph(m=24, n=16, k=16)
        acc = graph_executor.build(g, interpret=True)
        assert any(gk.n_tap == 1 for gk in acc.group_kernels.values())
        acc.validate()
        ops = g.random_operands(1)
        seq = graph_executor.build(g, interpret=True, merge=False)
        assert np.array_equal(np.asarray(acc(ops)), np.asarray(seq(ops)))

    def test_tap_bf16_dtype(self):
        g = tap_diamond_graph()
        acc = graph_executor.build(g, interpret=True,
                                   dtype=jnp.bfloat16)
        assert any(gk.n_tap == 1 for gk in acc.group_kernels.values())
        ops = g.random_operands(2)
        out = np.asarray(acc(ops), dtype=np.float64)
        ref = g.reference(ops)
        assert np.max(np.abs(out - ref) / (np.abs(ref) + 1.0)) < 2e-2
        seq = graph_executor.build(g, interpret=True, merge=False,
                                   dtype=jnp.bfloat16)
        assert np.array_equal(np.asarray(acc(ops)), np.asarray(seq(ops)))

    def test_tap_consumer_on_other_mesh_partition_priced(self):
        # the tap's out-of-group consumer takes the edge on its rhs,
        # whose partition disagrees with the producer's out shards on a
        # (1, 2) mesh -> the read is priced as an inter-chip reshard
        # while the producer's group still merges and exports the tap
        g = AlgebraGraph(
            nodes=(
                GraphNode(name="p", inputs=("x", "w0"), output="t",
                          algebra=small_gemm()),
                GraphNode(name="c1", inputs=("t", "w1"), output="y1",
                          algebra=small_gemm()),
                GraphNode(name="c2", inputs=("u", "t"), output="y2",
                          algebra=small_gemm()),
                GraphNode(name="fin", inputs=("y1", "y2"),
                          output="out", op="add"),
            ),
            inputs=("x", "w0", "w1", "u"), output="out")
        plan = plan_graph(g, mesh=(1, 2))
        grp = next(x for x in plan.groups if x.eligible)
        assert grp.taps == (("p", "t"),)
        e = next(e for e in plan.edges
                 if e.edge == "t" and e.consumer == "c2")
        assert not e.fused and e.reshard_bytes > 0
        assert "partition mismatch" in e.reason
        rep = plan.cost_report()
        assert rep.reshard_bytes.get("t", 0.0) > 0
        assert any(t.endswith(":t") for t in rep.tapped_edges)
        acc = graph_executor.build(g, plan=plan, interpret=True)
        assert grp.name in acc.group_kernels
        acc.validate()


# ---------------------------------------------------------------------------
# Whole-model graphs (ISSUE 10): the dense-family layer end to end
# ---------------------------------------------------------------------------

class TestModelLayer:
    def _graph(self):
        from repro.graph import from_model
        return from_model.transformer_layer_graph(l=32, d=32, dv=32,
                                                  f=64)

    def test_model_layer_merges_attention_and_mlp(self):
        plan = plan_graph(self._graph())
        groups = [g for g in plan.groups if g.eligible]
        assert len(groups) == 1
        grp = groups[0]
        assert grp.kind == "dag" and len(grp.dag) == 8
        for member in ("scores", "attend", "up", "down"):
            assert member in grp.stages
        assert grp.taps == (("oproj", "r1"),)
        # the PR 9 fallback reasons must be gone for registry gemms
        for e in plan.edges:
            assert "batched" not in e.reason
            assert "transposed" not in e.reason
        # k and vt land on consumer rhs sides, q/p/a/r1/h on lhs
        sides = {(e.edge, e.consumer): e.side
                 for e in plan.edges if e.fused}
        assert sides[("k", "scores")] == "rhs"
        assert sides[("vt", "attend")] == "rhs"
        assert sides[("r1", "up")] == "lhs"

    def test_model_layer_bit_parity_vs_forward(self):
        from repro.graph import from_model
        g = self._graph()
        ops = g.random_operands(0)
        acc = graph_executor.build(g, interpret=True)
        assert len(acc.group_kernels) == 1
        out = np.asarray(acc(ops))
        oracle = np.asarray(from_model.layer_oracle(ops))
        assert np.array_equal(out, oracle)
        seq = graph_executor.build(g, interpret=True, merge=False)
        assert np.array_equal(out, np.asarray(seq(ops)))
        acc.validate()

    def test_model_layer_from_config(self):
        from repro.configs.registry import get_config
        from repro.graph import from_model
        cfg = get_config("granite-8b").reduced()
        g = from_model.layer_graph_from_config(cfg, l=16)
        assert g.edge_shape("x") == (16, cfg.d_model)
        assert g.edge_shape("h_raw") == (16, cfg.d_ff)
        bad = get_config("mamba2-370m").reduced()
        with pytest.raises(ValueError, match="dense"):
            from_model.layer_graph_from_config(bad)

    def test_model_layer_batched_producer_fuses(self):
        # "producer lowering is batched" is gone: an effective-2D
        # batched_gemv producer merges into its gemm consumer
        g = AlgebraGraph(
            nodes=(
                GraphNode(name="bv", inputs=("A3", "v"), output="t",
                          algebra=get_algebra("batched_gemv",
                                              m=16, k=8, n=16)),
                GraphNode(name="c1", inputs=("t", "w"), output="y",
                          algebra=small_gemm()),
            ),
            inputs=("A3", "v", "w"), output="y")
        plan = plan_graph(g)
        e = next(e for e in plan.edges if e.edge == "t")
        assert e.fused
        grp = next(x for x in plan.groups if x.eligible)
        assert grp.kind == "dag"
        assert [s.kind for s in grp.dag] == ["batched", "dot"]
        acc = graph_executor.build(g, plan=plan, interpret=True)
        assert grp.name in acc.group_kernels
        acc.validate()
        ops = g.random_operands(3)
        seq = graph_executor.build(g, interpret=True, merge=False)
        assert np.array_equal(np.asarray(acc(ops)), np.asarray(seq(ops)))


# ---------------------------------------------------------------------------
# describe() surfaces fallback reasons (ISSUE 10 satellite)
# ---------------------------------------------------------------------------

class TestDescribeReasons:
    def test_describe_surfaces_ineligible_reason(self):
        # a VMEM-starved config declines the merge; the group's reason
        # string must appear verbatim in the accelerator's describe()
        g = chain_graph()
        cfg = dse.ArrayConfig(vmem_budget_bytes=256)
        plan = plan_graph(g, cfg=cfg)
        grp = plan.groups[0]
        assert not grp.eligible and grp.reason
        acc = graph_executor.build(g, plan=plan, cfg=cfg,
                                   interpret=True)
        text = acc.describe()
        assert f"sequential {grp.name}: {grp.reason}" in text

    def test_describe_surfaces_merge_disabled(self):
        g = chain_graph()
        acc = graph_executor.build(g, interpret=True, merge=False)
        assert "merging disabled (merge=False)" in acc.describe()

    def test_describe_surfaces_merged_knobs(self):
        g = chain_graph()
        acc = graph_executor.build(g, interpret=True)
        grp = next(x for x in acc.plan.groups if x.eligible)
        assert f"merged {grp.name}" in acc.describe()


# ---------------------------------------------------------------------------
# Tune-cache groups-map robustness (ISSUE 10 satellite)
# ---------------------------------------------------------------------------

def _write_group_entry(digest, entry):
    import json
    path = tune_cache.cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({
        "version": tune_cache.SCHEMA_VERSION,
        "variants": {}, "choices": {},
        "groups": {digest: entry},
    }))


class TestTuneCacheGroups:
    def _digest(self, plan, grp):
        return tune_cache.key_of(
            pipeline._group_cache_key(plan, grp, True, "pallas"))

    def test_group_corrupt_entry_warns_and_falls_back(self):
        g = chain_graph()
        plan = plan_graph(g)
        grp = next(x for x in plan.groups if x.eligible)
        digest = self._digest(plan, grp)
        _write_group_entry(digest, {"version": tune_cache.SCHEMA_VERSION,
                                    "merged": "yes"})
        with pytest.warns(RuntimeWarning, match="corrupt or version"):
            assert tune_cache.lookup_group(digest) is None
        assert tune_cache.cache_info()["invalid"] >= 1
        # the lower path degrades to the analytical merge, not a crash
        with pytest.warns(RuntimeWarning, match="corrupt or version"):
            acc = graph_executor.build(g, plan=plan, interpret=True)
        assert grp.name in acc.group_kernels
        assert acc.group_kernels[grp.name].source == "analytical"
        acc.validate()

    def test_group_version_skew_warns_and_falls_back(self):
        g = chain_graph()
        plan = plan_graph(g)
        grp = next(x for x in plan.groups if x.eligible)
        digest = self._digest(plan, grp)
        _write_group_entry(digest,
                           {"version": tune_cache.SCHEMA_VERSION + 1,
                            "merged": True, "bm": 16,
                            "interleave": "chain"})
        with pytest.warns(RuntimeWarning, match="corrupt or version"):
            assert tune_cache.lookup_group(digest) is None
        with pytest.warns(RuntimeWarning, match="corrupt or version"):
            acc = graph_executor.build(g, plan=plan, interpret=True)
        assert acc.group_kernels[grp.name].source == "analytical"
        acc.validate()

    def test_group_unreadable_file_warns_and_falls_back(self):
        path = tune_cache.cache_path()
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text("{ this is not json")
        with pytest.warns(RuntimeWarning, match="unreadable"):
            assert tune_cache.lookup_group("deadbeef") is None
        assert tune_cache.cache_info()["corrupt"] >= 1
        g = chain_graph()
        acc = graph_executor.build(g, interpret=True)
        assert acc.group_kernels
        acc.validate()
