"""Algebra graphs (PR 8): IR validation, planning, fusion, execution.

Covers the graph tentpole's contract surface:

* IR construction catches bad wiring (cycles, shape mismatches, unknown
  edges) at build time,
* a single-node graph degenerates bit-exactly to ``generate(alg)`` and
  shares its compile-cache entry,
* the attention+MLP chain is bit-identical to the explicit-schedule
  oracle with strictly fewer HBM bytes than the unfused pricing,
* non-fusable edges (B-side operand, dtype change) fall back to an HBM
  materialization with the cost charged,
* a diamond DAG executes its shared producer exactly once,
* the tuning cache never replays a standalone variant for a fused-group
  or epilogue'd lowering (the ``_cache_key`` regression).
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.compile import pipeline
from repro.core.algebra import get_algebra
from repro.core.costmodel import GraphCostReport
from repro.core import dse
from repro.graph import AlgebraGraph, GraphNode, plan_graph
from repro.graph import executor as graph_executor
from repro.kernels import fused_chain
from repro.models import chains
from repro.tune import cache as tune_cache


def small_gemm(m=16, n=16, k=16):
    return get_algebra("gemm", m=m, n=n, k=k)


def single_node_graph():
    return AlgebraGraph(
        nodes=(GraphNode(name="mm", inputs=("A", "B"), output="C",
                         algebra=small_gemm()),),
        inputs=("A", "B"), output="C")


def chain_graph():
    """gemm -> gelu -> gemm, all fusable (the quickstart shape)."""
    return AlgebraGraph(
        nodes=(
            GraphNode(name="g1", inputs=("x", "W1"), output="h_raw",
                      algebra=small_gemm()),
            GraphNode(name="act", inputs=("h_raw",), output="h",
                      op="gelu"),
            GraphNode(name="g2", inputs=("h", "W2"), output="y",
                      algebra=small_gemm()),
        ),
        inputs=("x", "W1", "W2"), output="y")


# ---------------------------------------------------------------------------
# IR validation
# ---------------------------------------------------------------------------

class TestIR:
    def test_cycle_rejected(self):
        with pytest.raises(ValueError, match="cycle"):
            AlgebraGraph(
                nodes=(GraphNode(name="a", inputs=("y",), output="x",
                                 op="relu"),
                       GraphNode(name="b", inputs=("x",), output="y",
                                 op="relu")),
                inputs=(), output="y")

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError, match="shape mismatch"):
            AlgebraGraph(
                nodes=(GraphNode(name="g1", inputs=("x", "W"), output="h",
                                 algebra=small_gemm(16, 32, 16)),
                       GraphNode(name="g2", inputs=("h", "V"), output="y",
                                 algebra=small_gemm(16, 16, 16))),
                inputs=("x", "W", "V"), output="y")

    def test_unknown_edge_rejected(self):
        with pytest.raises(ValueError, match="unknown edge"):
            AlgebraGraph(
                nodes=(GraphNode(name="g", inputs=("x", "nope"),
                                 output="y", algebra=small_gemm()),),
                inputs=("x",), output="y")

    def test_duplicate_producer_rejected(self):
        with pytest.raises(ValueError, match="produced by both"):
            AlgebraGraph(
                nodes=(GraphNode(name="a", inputs=("x",), output="y",
                                 op="relu"),
                       GraphNode(name="b", inputs=("x",), output="y",
                                 op="tanh")),
                inputs=("x",), output="y")

    def test_epilogue_arity(self):
        with pytest.raises(ValueError, match="input edge"):
            GraphNode(name="b", inputs=("x",), output="y", op="bias")

    def test_reference_matches_manual(self):
        g = chain_graph()
        ops = g.random_operands(0)
        h = ops["x"].astype(np.float64) @ ops["W1"].T.astype(np.float64)
        from repro.kernels.epilogue import apply_epilogue_np
        want = apply_epilogue_np(h, ("gelu",)) @ ops["W2"].T
        got = g.reference(ops)
        np.testing.assert_allclose(got, want, atol=1e-9)


# ---------------------------------------------------------------------------
# Degeneration: one node == generate(alg)
# ---------------------------------------------------------------------------

class TestSingleNode:
    def test_bit_exact_and_cache_shared(self):
        g = single_node_graph()
        acc_g = repro.generate(g)
        acc_a = repro.generate(small_gemm())
        # the unconstrained node lowers with no fused_group/epilogue and
        # therefore shares the standalone compile-cache entry
        assert acc_g.kernels["mm"] is acc_a.kernel
        ops = g.random_operands(0)
        got = np.asarray(acc_g(ops))
        want = np.asarray(acc_a({"A": ops["A"], "B": ops["B"]}))
        assert (got == want).all()

    def test_cost_report_shape(self):
        rep = repro.generate(single_node_graph()).cost_report()
        assert isinstance(rep, GraphCostReport)
        assert rep.fused_edges == ()
        assert rep.hbm_bytes == rep.hbm_bytes_unfused  # nothing to fuse
        assert rep.cycles > 0


# ---------------------------------------------------------------------------
# Fusion: chain parity + honest byte accounting
# ---------------------------------------------------------------------------

class TestFusedChains:
    def test_gelu_chain_fuses_and_validates(self):
        g = chain_graph()
        acc = repro.generate(g)
        p = acc.plan.nodes["g1"]
        assert p.epilogue == ("gelu",) and p.epilogue_fused
        rep = acc.cost_report()
        assert len(rep.fused_edges) == 1
        assert rep.hbm_bytes < rep.hbm_bytes_unfused
        acc.validate(seed=0)

    def test_attention_mlp_bit_parity(self):
        g = chains.attention_mlp_graph(lq=32, lkv=32, d=32, dv=32, f=64)
        acc = repro.generate(g)
        ops = g.random_operands(1)
        got = np.asarray(acc(ops))
        want = np.asarray(chains.attention_mlp_oracle(
            {k: v for k, v in ops.items()}))
        assert got.shape == want.shape
        assert (got == want).all(), (
            f"max err {np.abs(got - want).max():.3e}")

    def test_attention_mlp_fewer_hbm_bytes(self):
        g = chains.attention_mlp_graph(lq=32, lkv=32, d=32, dv=32, f=64)
        rep = repro.generate(g).cost_report()
        assert len(rep.fused_edges) == 3     # probs, attn, mlp_h
        assert rep.hbm_bytes < rep.hbm_bytes_unfused
        assert rep.saved_hbm_bytes > 0
        assert rep.hbm_ratio > 1.3
        # the softmax/gelu epilogues are folded into the gemm kernels
        plan = repro.generate(g).plan
        assert (plan.nodes["scores"].epilogue ==
            (chains._scale_op(32), "softmax"))
        assert plan.nodes["mlp_up"].epilogue == ("bias", "gelu")

    def test_search_graph_returns_plan(self):
        g = chain_graph()
        plan = dse.search_graph(g, search=2)
        assert set(plan.nodes) == {"g1", "g2"}
        rep = plan.cost_report()
        assert rep.cycles > 0 and rep.hbm_bytes <= rep.hbm_bytes_unfused


# ---------------------------------------------------------------------------
# Non-fusable edges fall back to materialization, cost charged
# ---------------------------------------------------------------------------

class TestMaterialization:
    def b_side_graph(self):
        """g2 consumes g1's output as its *B* operand (stored
        transposed by gemm's prepare) — never fusable."""
        return AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h",
                          algebra=small_gemm()),
                GraphNode(name="g2", inputs=("y2", "h"), output="z",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W1", "y2"), output="z")

    def test_b_side_edge_materializes(self):
        g = self.b_side_graph()
        acc = repro.generate(g)
        rep = acc.cost_report()
        assert rep.fused_edges == ()
        mats = dict(rep.materialized_edges)
        assert any("transposed" in why for why in mats.values())
        # the materialized edge is charged: write + read of 16x16 fp32
        assert rep.edge_bytes["h"] == 2 * 16 * 16 * 4
        acc.validate(seed=0)

    def test_dtype_change_blocks_fusion(self):
        g = AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h",
                          algebra=small_gemm()),
                GraphNode(name="g2", inputs=("h", "W2"), output="y",
                          algebra=small_gemm(), dtype="bfloat16"),
            ),
            inputs=("x", "W1", "W2"), output="y")
        plan = plan_graph(g)
        edge = next(e for e in plan.edges if e.producer == "g1")
        assert not edge.fused and "dtype" in edge.reason
        rep = plan.cost_report()
        assert rep.fused_edges == ()

    def test_fanout_blocks_epilogue_folding(self):
        # h_raw has two consumers: the epilogue cannot fold into g1
        g = AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h_raw",
                          algebra=small_gemm()),
                GraphNode(name="act", inputs=("h_raw",), output="h",
                          op="relu"),
                GraphNode(name="g2", inputs=("h", "W2"), output="y1",
                          algebra=small_gemm()),
                GraphNode(name="g3", inputs=("h_raw", "W3"), output="y2",
                          algebra=small_gemm()),
                GraphNode(name="last", inputs=("y1", "y2"), output="z",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W1", "W2", "W3"), output="z")
        acc = repro.generate(g)
        assert acc.plan.nodes["g1"].epilogue == ()
        # the standalone relu node pays its round trip in the pricing
        assert acc.cost_report().edge_bytes["h"] > 0
        acc.validate(seed=1)


# ---------------------------------------------------------------------------
# Diamond DAG: shared producer executes once
# ---------------------------------------------------------------------------

class TestDiamond:
    def diamond(self):
        return AlgebraGraph(
            nodes=(
                GraphNode(name="p", inputs=("x", "W"), output="c",
                          algebra=small_gemm()),
                GraphNode(name="q1", inputs=("c", "W1"), output="o1",
                          algebra=small_gemm()),
                GraphNode(name="q2", inputs=("c", "W2"), output="o2",
                          algebra=small_gemm()),
                GraphNode(name="r", inputs=("o1", "o2"), output="z",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W", "W1", "W2"), output="z")

    def test_producer_runs_once(self, monkeypatch):
        # merge=False: the PR 8 sequential path — one dispatch per node
        g = self.diamond()
        acc = graph_executor.build(g, interpret=True, merge=False)
        calls = []
        orig = pipeline.CompiledKernel.__call__

        def counting(self, operands):
            calls.append(self.algebra.name)
            return orig(self, operands)

        monkeypatch.setattr(pipeline.CompiledKernel, "__call__", counting)
        ops = g.random_operands(0)
        got = np.asarray(acc(ops))
        assert len(calls) == 4        # p, q1, q2, r — p not re-computed
        np.testing.assert_allclose(
            got, g.reference(ops).astype(np.float64), atol=1e-3)

    def test_producer_runs_once_merged(self, monkeypatch):
        # default path: q1->r merges (o1 is sole-consumed) so only p and
        # q2 dispatch per-node; p still runs exactly once
        g = self.diamond()
        acc = repro.generate(g)
        assert list(acc.group_kernels) == ["mg:q1+r"]
        calls, group_calls = [], []
        orig = pipeline.CompiledKernel.__call__
        gorig = pipeline.CompiledGroupKernel.__call__

        def counting(self, operands):
            calls.append(self.algebra.name)
            return orig(self, operands)

        def gcounting(self, lhs, rhss, biases=()):
            group_calls.append(self.group)
            return gorig(self, lhs, rhss, biases)

        monkeypatch.setattr(pipeline.CompiledKernel, "__call__", counting)
        monkeypatch.setattr(pipeline.CompiledGroupKernel, "__call__",
                            gcounting)
        ops = g.random_operands(0)
        got = np.asarray(acc(ops))
        assert len(calls) == 2            # p, q2 — p not re-computed
        # one megakernel dispatch (its .group label may name another
        # graph's structurally-identical chain — entries are shared)
        assert len(group_calls) == 1
        np.testing.assert_allclose(
            got, g.reference(ops).astype(np.float64), atol=1e-3)

    def test_fanout_edge_priced_per_consumer(self):
        rep = plan_graph(self.diamond()).cost_report()
        # c fans out to two consumers: at most one write + unfused reads
        # are charged; both q-edges into r can never both fuse (B side)
        assert rep.hbm_bytes <= rep.hbm_bytes_unfused


# ---------------------------------------------------------------------------
# Tuning-cache keys: fused-group / epilogue never alias standalone
# ---------------------------------------------------------------------------

class TestTuneCacheKeys:
    def test_fused_group_not_served_standalone_variant(self):
        alg = small_gemm()
        df = pipeline.default_dataflow(alg)
        base = pipeline._cache_key(alg, df, pipeline.ArrayConfig(),
                                   "float32", True, "pallas")
        tune_cache.store_variant(tune_cache.key_of(base),
                                 blocks=(8, 8, 8), grid_order="mnk",
                                 accum="scratch")
        pipeline.cache_clear()
        plain = pipeline.lower(alg, df, interpret=True)
        assert plain.source == "tuned" and plain.blocks == (8, 8, 8)
        fused = pipeline.lower(alg, df, interpret=True,
                               fused_group="g:test")
        assert fused.source == "analytical" and fused.blocks != (8, 8, 8)
        epi = pipeline.lower(alg, df, interpret=True, epilogue=("relu",))
        assert epi.source == "analytical"


# ---------------------------------------------------------------------------
# Merged-kernel execution (ISSUE 9): one pallas_call per fused chain
# ---------------------------------------------------------------------------

def group_operands(group, ops):
    """The group's external operands picked out of a graph operand dict."""
    return (ops[group.lhs_edge],
            [ops[e] for e in group.rhs_edges],
            [ops[e] for e in group.bias_edges if e is not None])


class TestMergedKernel:
    def test_merged_single_pallas_call(self, monkeypatch):
        # the acceptance chain: gemm·gelu·gemm runs as ONE megakernel —
        # zero per-node dispatches — and is bit-exact vs the sequential
        # path (bm == m: identical dot + epilogue sequence)
        g = chain_graph()
        acc = repro.generate(g)
        assert list(acc.group_kernels) == ["mg:g1+g2"]
        seq = graph_executor.build(g, interpret=True, merge=False)
        ops = g.random_operands(0)
        want_seq = np.asarray(seq(ops))

        calls, group_calls = [], []
        orig = pipeline.CompiledKernel.__call__
        gorig = pipeline.CompiledGroupKernel.__call__
        monkeypatch.setattr(
            pipeline.CompiledKernel, "__call__",
            lambda self, operands: calls.append(self.algebra.name)
            or orig(self, operands))
        monkeypatch.setattr(
            pipeline.CompiledGroupKernel, "__call__",
            lambda self, lhs, rhss, biases=():
            group_calls.append(self.group) or gorig(self, lhs, rhss, biases))
        got = np.asarray(acc(ops))
        assert calls == []                 # nothing dispatched per-node
        assert len(group_calls) == 1       # the whole chain: ONE pallas_call
        np.testing.assert_array_equal(got, want_seq)      # bit-exact
        np.testing.assert_allclose(
            got, g.reference(ops).astype(np.float64), atol=1e-3)

    def test_merged_attention_mlp_parity(self):
        # the scores->softmax->attend pair + MLP merges into one chain,
        # still matching the numpy graph oracle and the sequential path
        g = chains.attention_mlp_graph(lq=32, lkv=32, d=32, dv=32, f=64)
        acc = repro.generate(g)
        assert list(acc.group_kernels) == ["mg:scores+attend+mlp_up+mlp_down"]
        gk = acc.group_kernels["mg:scores+attend+mlp_up+mlp_down"]
        assert gk.bm == gk.m              # whole-tensor degenerate phase
        seq = graph_executor.build(g, interpret=True, merge=False)
        ops = g.random_operands(0)
        np.testing.assert_array_equal(np.asarray(acc(ops)),
                                      np.asarray(seq(ops)))
        acc.validate()

    def test_merged_nondivisible_m_blocks(self):
        # m=24 against bm in {7, 16}: the pad-to-multiple + slice path,
        # on both stage interleaves
        g = AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h_raw",
                          algebra=small_gemm(m=24, n=32, k=16)),
                GraphNode(name="act", inputs=("h_raw",), output="h",
                          op="gelu"),
                GraphNode(name="g2", inputs=("h", "W2"), output="y",
                          algebra=small_gemm(m=24, n=16, k=32)),
            ),
            inputs=("x", "W1", "W2"), output="y")
        plan = plan_graph(g)
        grp = next(x for x in plan.groups if x.eligible)
        ops = g.random_operands(0)
        want = np.asarray(g.reference(ops), np.float64)
        bound = 1e-3 + 1e-5 * np.abs(want).max()
        lhs, rhss, biases = group_operands(grp, ops)
        for bm in (7, 16):
            for il in fused_chain.FUSED_INTERLEAVES:
                gk = pipeline.lower_group(plan, grp, interpret=True,
                                          bm=bm, interleave=il)
                got = np.asarray(gk(lhs, rhss, biases), np.float64)
                assert got.shape == want.shape
                assert np.abs(got - want).max() <= bound, (bm, il)

    def test_merged_bf16_chain(self):
        # validate=False: the per-node lower-time oracle check uses an
        # fp32 atol; the bf16-tolerance oracle comparison happens below
        g = chain_graph()
        acc = graph_executor.build(g, interpret=True, dtype=jnp.bfloat16,
                                   validate=False)
        assert list(acc.group_kernels) == ["mg:g1+g2"]
        seq = graph_executor.build(g, interpret=True, dtype=jnp.bfloat16,
                                   merge=False, validate=False)
        ops = g.random_operands(0)
        got = np.asarray(acc(ops), np.float64)
        # same per-stage math (fp32 dot, fp32 epilogue, bf16 cast between
        # stages) in the same order: bit-equal to sequential dispatch
        np.testing.assert_array_equal(got, np.asarray(seq(ops), np.float64))
        want = np.asarray(g.reference(ops), np.float64)
        scale = np.abs(want).max() + 1e-30
        assert np.abs(got - want).max() / scale <= 2e-2

    def test_merged_vmem_overflow_falls_back(self):
        # a budget too small for the intermediate strip: the planner
        # keeps the group as documentation (eligible=False) and the
        # executor stays sequential — still matching the oracle
        from repro.core.tiling import ArrayConfig
        g = chain_graph()
        cfg = ArrayConfig(vmem_budget_bytes=2048)
        plan = plan_graph(g, cfg=cfg)
        assert plan.groups and not plan.groups[0].eligible
        assert "VMEM" in plan.groups[0].reason
        acc = graph_executor.build(g, plan=plan, interpret=True, cfg=cfg)
        assert not acc.group_kernels
        acc.validate()

    def test_merged_sequential_verdict_respected(self):
        # a persisted merged=False verdict (sequential measured faster)
        # makes lower_group decline and build() keep per-node dispatch
        g = chain_graph()
        plan = plan_graph(g)
        grp = next(x for x in plan.groups if x.eligible)
        digest = tune_cache.key_of(
            pipeline._group_cache_key(plan, grp, True, "pallas"))
        tune_cache.store_group(digest, merged=False)
        assert pipeline.lower_group(plan, grp, interpret=True) is None
        acc = graph_executor.build(g, plan=plan, interpret=True)
        assert not acc.group_kernels
        acc.validate()

    def test_merged_tune_group_verdict_cached(self):
        from repro.tune import tuner
        g = chain_graph()
        plan = plan_graph(g)
        grp = next(x for x in plan.groups if x.eligible)
        res = tuner.tune_group(plan, grp, interpret=True,
                               repeats=1, warmup=0)
        assert not res.cache_hit and res.trials
        assert all(t.ok for t in res.trials)
        res2 = tuner.tune_group(plan, grp, interpret=True)
        assert res2.cache_hit and res2.merged == res.merged
        # and build(tune=...) consumes the same verdict without measuring
        acc = graph_executor.build(g, plan=plan, interpret=True, tune=8)
        assert acc.group_tuning[grp.name].cache_hit
        assert bool(acc.group_kernels) == res.merged
        acc.validate()

    def test_merged_bias_key_collision_rejected(self):
        # regression (ISSUE 9 bugfix): a tensor name inside the reserved
        # "bias:" operand namespace would silently shadow the injected
        # bias vector; build() must reject it
        g = AlgebraGraph(
            nodes=(GraphNode(name="mm", inputs=("bias:x", "B"),
                             output="C", algebra=small_gemm()),),
            inputs=("bias:x", "B"), output="C")
        with pytest.raises(ValueError, match="bias:"):
            graph_executor.build(g, interpret=True)

    def test_merged_group_cache_key_separates_epilogues(self):
        # two chains identical but for one stage's folded epilogue must
        # not share a merged compile/tune cache entry
        g1 = chain_graph()
        g2 = AlgebraGraph(
            nodes=(
                GraphNode(name="g1", inputs=("x", "W1"), output="h_raw",
                          algebra=small_gemm()),
                GraphNode(name="act", inputs=("h_raw",), output="h",
                          op="relu"),
                GraphNode(name="g2", inputs=("h", "W2"), output="y",
                          algebra=small_gemm()),
            ),
            inputs=("x", "W1", "W2"), output="y")
        p1, p2 = plan_graph(g1), plan_graph(g2)
        k1 = pipeline._group_cache_key(p1, p1.groups[0], True, "pallas")
        k2 = pipeline._group_cache_key(p2, p2.groups[0], True, "pallas")
        assert k1 != k2

    def test_variant_stored_for_fused_group_is_found(self):
        alg = small_gemm()
        df = pipeline.default_dataflow(alg)
        key = pipeline._cache_key(alg, df, pipeline.ArrayConfig(),
                                  "float32", True, "pallas",
                                  fused_group="g:test")
        tune_cache.store_variant(tune_cache.key_of(key),
                                 blocks=(4, 4, 4), grid_order="kmn",
                                 accum="inplace")
        pipeline.cache_clear()
        fused = pipeline.lower(alg, df, interpret=True,
                               fused_group="g:test")
        assert fused.source == "tuned" and fused.blocks == (4, 4, 4)
