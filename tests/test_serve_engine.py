"""serve/engine.py satellites (ISSUE 7): ServeConfig default-sharing
regression + AcceleratorEngine thread-safety under concurrent submits."""
import threading

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params, split
from repro.serve import AcceleratorEngine, DecodeEngine, ServeConfig


# ---------------------------------------------------------------------------
# ServeConfig must not be shared across engines (mutable-default bug)
# ---------------------------------------------------------------------------

def test_decode_engines_do_not_share_default_serve_config():
    cfg = get_config("granite-8b").reduced()
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
    a = DecodeEngine(params, cfg)
    b = DecodeEngine(params, cfg)
    assert a.serve_cfg is not b.serve_cfg
    a.serve_cfg.eos_id = 7
    a.serve_cfg.max_new_tokens = 99
    assert b.serve_cfg.eos_id is None      # b must be unaffected
    assert b.serve_cfg.max_new_tokens == 32


def test_explicit_serve_config_is_used_as_given():
    cfg = get_config("granite-8b").reduced()
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
    scfg = ServeConfig(max_new_tokens=3)
    eng = DecodeEngine(params, cfg, scfg)
    assert eng.serve_cfg is scfg
    gen, _ = eng.generate(np.ones((1, 4), np.int32))
    assert gen.shape == (1, 3)


# ---------------------------------------------------------------------------
# AcceleratorEngine: concurrent submits
# ---------------------------------------------------------------------------

def test_accelerator_engine_concurrent_submits():
    """8 threads x mixed algebras/shapes: every result matches the
    reference einsum, the handle cache holds one accelerator per request
    signature, and the stats counter equals the number of submits."""
    engine = AcceleratorEngine(interpret=True)
    shapes = [{"m": 16, "k": 16, "n": 16}, {"m": 32, "k": 16, "n": 16}]
    per_thread = 3
    n_threads = 8
    errors = []

    def worker(tid):
        rng = np.random.default_rng(tid)   # Generators are not thread-safe
        try:
            for i in range(per_thread):
                bounds = shapes[(tid + i) % len(shapes)]
                a = jnp.asarray(rng.standard_normal(
                    (bounds["m"], bounds["k"])).astype(np.float32))
                b = jnp.asarray(rng.standard_normal(
                    (bounds["n"], bounds["k"])).astype(np.float32))
                out = engine.submit("gemm", {"A": a, "B": b}, bounds=bounds)
                # paper layout: C[m,n] += A[m,k] * B[n,k]
                want = np.asarray(a) @ np.asarray(b).T
                np.testing.assert_allclose(np.asarray(out), want,
                                           rtol=1e-4, atol=1e-4)
        except Exception as e:             # surfaced after join
            errors.append((tid, e))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    stats = engine.stats()
    assert stats["requests"] == n_threads * per_thread
    assert stats["algebras"] == ["gemm"]
    # one cached handle per distinct request signature — racing submits
    # must not have stamped duplicates over each other
    assert len(engine._accs) == len(shapes)
