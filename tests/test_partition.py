"""Unit tests for the unified partition solver (ISSUE 5 tentpole).

``plan.solve_partition`` is jax-free, so everything here runs on the
single pytest device: solver decisions (batch sharding, compressed
shipping, dt staggering, degradations), byte/MAC accounting, the batched
sparse slice-skipping lowering, and the mesh-priced cost model / DSE.
Multi-device parity runs in ``repro/dist/partition_selftest.py`` (see
test_distributed.py).
"""
import math

import pytest

import repro
from repro.compile.lowering import lower_form
from repro.core import algebra, costmodel, dse, stt
from repro.core.algebra import Sparsity
from repro.core.plan import comm_plan_for, solve_partition


def solved(alg, dfname="output_stationary", shape=(2, 4), **kw):
    df = stt.apply_stt(alg, alg.loops[:3], stt.stt_from_name(dfname))
    comm = comm_plan_for(df, densities={name: alg.density_of(name)
                                        for name, _ in alg.sparsity})
    return (solve_partition(comm, lower_form(alg), shape=shape, **kw),
        lower_form(alg))


# ---------------------------------------------------------------------------
# Solver decisions
# ---------------------------------------------------------------------------

def test_classic_strategies_recovered():
    g = algebra.gemm(16, 16, 16)
    assert solved(g, "identity")[0].strategy == "summa"
    assert solved(g, "output_stationary", (2, 2))[0].strategy == "cannon"
    assert solved(g, "weight_stationary")[0].strategy == "k_spatial_stagger"


def test_batch_folds_onto_mesh_axis():
    bg = algebra.get_algebra("batched_gemv", m=8, k=8, n=8)
    for dfname in ("identity", "output_stationary", "weight_stationary",
                   "input_stationary"):
        sol, form = solved(bg, dfname)
        assert sol.batch_axis is not None, dfname
        assert sol.out.axis_of["b"] == sol.batch_axis
        # the batch shard shows up as a MAC split (work scales 1/axis)
        assert sol.macs_split % sol.sizes[sol.batch_axis] == 0
        assert not sol.replicated_inputs()


def test_batch_replication_only_as_degenerate_solution():
    bg = algebra.get_algebra("batched_gemv", m=8, k=8, n=8)
    sol, _ = solved(bg, shard_batch=False)
    assert sol.batch_axis is None          # explicit baseline request
    # diagonal reduction outputs use both axes for the tree: no axis left
    g = algebra.gemm(8, 8, 8)
    df = stt.apply_stt(g, g.loops, stt.stt_from_name("identity"))
    # (gemm is unbatched; just assert the solver accepts a 2-axis k tree)
    comm = comm_plan_for(df)
    sol = solve_partition(comm, lower_form(g), shape=(2, 4))
    assert sol.strategy == "summa"


def test_rect_mesh_keeps_one_systolic_ring():
    """Cannon-class plans on rectangular meshes keep dt on one ring
    instead of collapsing both inputs to all_gather replication."""
    g = algebra.gemm(16, 16, 16)
    sol, _ = solved(g, "output_stationary", (2, 4))
    assert sol.strategy == "ring_hybrid"
    rings = [tp.motion for tp in (sol.lhs, sol.rhs)]
    assert rings.count("ppermute_ring") == 1
    assert any("degraded to all_gather" in n for n in sol.notes)
    # square meshes still run the double ring
    assert solved(g, "output_stationary", (2, 2))[0].strategy == "cannon"


def test_stagger_solution_shape():
    g = algebra.gemm(16, 16, 16)
    sol, form = solved(g, "weight_stationary", (2, 4))
    assert sol.stagger and sol.out.motion == "ppermute_ring"
    ring = sol.ring_axes[0]
    assert sol.out.axis_of["m"] == ring
    S = sol.sizes[ring]
    # mobile tensor (the rotating output) stores <= 1/S of a replica
    out_b = sol.per_device_bytes(form)["out"]
    assert out_b * S <= form.m * form.n * 4


def test_compressed_side_and_metadata_bytes():
    sp = Sparsity.random((16, 16), (4, 4), 0.25, seed=7)
    alg = algebra.gemm(16, 16, 16).with_sparsity(A=sp)
    sol, form = solved(alg, "identity", (2, 2))
    assert sol.lhs.compressed and not sol.rhs.compressed
    bytes_c = sol.per_device_bytes(form)["lhs"]
    dense_sol, _ = solved(alg, "identity", (2, 2), compressed=False)
    assert not dense_sol.lhs.compressed
    bytes_d = dense_sol.per_device_bytes(form)["lhs"]
    # payload = density x dense shard, plus 2 int32 coords per nnz block
    dense_shard = (16 // 2) * (16 // 2) * 4
    assert bytes_d == pytest.approx(dense_shard)
    assert bytes_c == pytest.approx(0.25 * dense_shard
                                    + 0.25 * (dense_shard / (4 * 4 * 4))
                                    * 8)
    # comm bytes: the moving side pays per-hop shard bytes
    hops = sol.sizes[sol.lhs.motion_axis] - 1
    assert sol.comm_bytes(form)["lhs"] == pytest.approx(bytes_c * hops)


def test_batched_forms_never_compress():
    sp = Sparsity((2, 2), ((0, 0),))
    alg = (algebra.get_algebra("batched_gemv", m=8, k=8, n=8)
        .with_sparsity(B=sp))
    sol, form = solved(alg)
    assert not sol.lhs.compressed and not sol.rhs.compressed


def test_replicated_inputs_reported():
    g = algebra.gemm(16, 16, 16)
    for dfname in ("identity", "output_stationary", "weight_stationary",
                   "input_stationary"):
        for shape in ((1, 1), (1, 8), (8, 1), (2, 4)):
            sol, _ = solved(g, dfname, shape)
            assert sol.replicated_inputs() == ()


# ---------------------------------------------------------------------------
# Batched sparse slice skipping (satellite)
# ---------------------------------------------------------------------------

def test_batched_sparse_skips_zero_slices():
    sp = Sparsity((2, 2), ((0, 0), (0, 1), (2, 0)))
    alg = (algebra.get_algebra("batched_gemv", m=8, k=8, n=8)
        .with_sparsity(B=sp))
    form = lower_form(alg)
    assert form.batch_keep == (0, 1, 4, 5)
    assert form.batch == (4,) and form.batch_full == (8,)
    assert form.executed_macs == 4 * form.m * form.n * form.k


def test_batched_sparse_ratio_drops_below_dense_execution():
    """The per-slice mapping makes executed_mac_ratio < 1/work_density
    (what full-batch masked-dense execution would pay)."""
    sp = Sparsity((2, 2), ((0, 0), (2, 0)))
    for name, bounds, tensor in (
            ("batched_gemv", dict(m=8, k=8, n=8), "B"),
            ("depthwise_conv", dict(k=8, y=5, x=5, p=2, q=2), "B")):
        alg = algebra.get_algebra(name, **bounds)
        t_shape = alg.tensor_shape(
            next(t for t in alg.tensors if t.name == tensor))
        spn = Sparsity.random(t_shape, (2,) * len(t_shape), 0.4, seed=3)
        alg = alg.with_sparsity(**{tensor: spn})
        acc = repro.generate(alg, interpret=True)
        rep = acc.cost_report()
        if acc.kernel.form.batch_keep is not None:
            assert rep.executed_mac_ratio < 1.0 / rep.work_density
        assert acc.validate() <= 1e-3


def test_batched_sparse_dense_pattern_keeps_all_slices():
    sp = Sparsity((2, 2), tuple((i, j) for i in range(4) for j in range(4)))
    alg = (algebra.get_algebra("batched_gemv", m=8, k=8, n=8)
        .with_sparsity(B=sp))
    form = lower_form(alg)
    assert form.batch_keep is None and form.batch == (8,)


# ---------------------------------------------------------------------------
# Mesh-priced cost model + DSE
# ---------------------------------------------------------------------------

def test_mesh_evaluate_fills_collective_terms():
    g = algebra.gemm(32, 32, 32)
    df = stt.apply_stt(g, g.loops, stt.stt_from_name("output_stationary"))
    rep = costmodel.mesh_evaluate(g, df, (2, 2))
    assert rep.mesh_shape == (2, 2) and rep.mesh_strategy == "cannon"
    assert rep.per_device_macs == rep.executed_macs // 4
    assert rep.mesh_cycles > 0
    assert set(rep.mesh_comm_bytes) == {"lhs", "rhs", "out"}
    # batch-shard speedup shows up in per-device compute
    bg = algebra.get_algebra("batched_gemv", m=8, k=8, n=8)
    dfb = stt.apply_stt(bg, bg.loops, stt.stt_from_name("output_stationary"))
    sharded = costmodel.mesh_evaluate(bg, dfb, (2, 4))
    repl = costmodel.mesh_evaluate(bg, dfb, (2, 4), shard_batch=False)
    assert sharded.per_device_macs < repl.per_device_macs


def test_mesh_evaluate_nnz_scaled_payload():
    sp = Sparsity.random((16, 16), (4, 4), 0.25, seed=7)
    g = algebra.gemm(16, 16, 16)
    df = stt.apply_stt(g, g.loops, stt.stt_from_name("identity"))
    dense = costmodel.mesh_evaluate(g, df, (2, 2))
    sparse = costmodel.mesh_evaluate(g.with_sparsity(A=sp), df, (2, 2))
    assert sparse.mesh_comm_bytes["lhs"] < dense.mesh_comm_bytes["lhs"]


def test_dse_search_mesh_ranks_by_multichip_cost():
    g = algebra.gemm(16, 16, 16)
    ranked = dse.search(g, top_k=5, mesh=(2, 4),
                        selections=[("m", "n", "k")])
    assert len(ranked) == 5
    costs = [rep.mesh_cycles for rep, _ in ranked]
    assert costs == sorted(costs)
    assert all(rep.mesh_shape == (2, 4) for rep, _ in ranked)
    # accepts a Mesh too (normalized to its shape) — exercised via tuple
    ranked2 = dse.search(g, top_k=2, mesh=(2, 4),
                         selections=[("m", "n", "k")])
    assert ranked2[0][0].mesh_cycles == ranked[0][0].mesh_cycles


# ---------------------------------------------------------------------------
# Pipeline / API surface
# ---------------------------------------------------------------------------

def test_compiled_kernel_partition_for():
    acc = repro.generate("gemm", bounds=dict(m=8, n=8, k=8), interpret=True)
    sol = acc.kernel.partition_for((2, 2))
    assert sol.strategy == "cannon"
    assert sol.grid["m"] == "x" and sol.grid["n"] == "y"


def test_accelerator_partition_requires_mesh():
    acc = repro.generate("gemm", bounds=dict(m=8, n=8, k=8), interpret=True)
    with pytest.raises(ValueError, match="mesh"):
        _ = acc.partition


def test_per_device_macs_accounting():
    bg = algebra.get_algebra("batched_gemv", m=8, k=8, n=8)
    sol, form = solved(bg, "output_stationary", (2, 4))
    # b over x(2), n over y(4): macs shrink 8x
    assert sol.per_device_macs(form) == form.executed_macs // 8
    assert sol.per_device_macs(form) * 8 == math.prod(bg.bounds)


def test_describe_reports_partition_and_comm_bytes():
    import jax
    import numpy as np
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("x", "y"))
    acc = repro.generate("gemm", bounds=dict(m=8, n=8, k=8),
                         interpret=True).sharded(mesh)
    text = acc.describe()
    assert "strategy=cannon" in text
    assert "lhs (A):" in text and "rhs (B):" in text
    assert "stored=" in text and "comm=" in text


def test_serve_engine_reports_partitions():
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.serve import AcceleratorEngine

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("x", "y"))
    eng = AcceleratorEngine(mesh=mesh, interpret=True)
    g = algebra.gemm(8, 8, 8)
    operands = g.random_operands(seed=4)
    out = eng.submit("gemm", operands, bounds=dict(m=8, n=8, k=8))
    import numpy.testing as npt
    npt.assert_array_equal(np.asarray(out).round().astype(np.int64),
                           g.reference(operands))
    st = eng.stats()
    assert st["partitions"]["gemm"]["strategy"] == "cannon"
    assert st["partitions"]["gemm"]["replicated_inputs"] == ()
    assert "strategy=cannon" in eng.describe("gemm",
                                             bounds=dict(m=8, n=8, k=8))
