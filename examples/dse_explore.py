"""Design-space exploration walkthrough (paper Fig. 6 in miniature).

Enumerates every distinct GEMM dataflow TensorLib can generate for one loop
selection, costs them with the paper's cycle/area/power model, prints the
Pareto frontier, and shows the mesh-level schedule each frontier point maps
to on a TPU pod.

    PYTHONPATH=src python examples/dse_explore.py
"""
from repro.core import algebra, dse, plan, stt
from repro.dist.schedules import schedule_from_comm_plan

g = algebra.gemm(512, 512, 512)
flows = dse.enumerate_dataflows(g, selections=[("m", "n", "k")])
print(f"distinct GEMM dataflows (one selection, |T entries| <= 1): "
      f"{len(flows)}")

reports = dse.sweep(g, selections=[("m", "n", "k")])
good = [r for r in reports if r.normalized_perf >= 0.5]
front = dse.pareto_front(good)
print(f"efficient points: {len(good)}; pareto frontier: {len(front)}\n")

by_name = {df.name: df for df in flows.values()}
print(f"{'dataflow':12s} {'perf':>6s} {'area':>7s} {'power':>7s}  mesh schedule")
for r in sorted(front, key=lambda r: -r.normalized_perf)[:10]:
    df = by_name.get(r.dataflow_name)
    sched = schedule_from_comm_plan(plan.comm_plan_for(df)) if df else "?"
    print(f"{r.dataflow_name:12s} {r.normalized_perf:6.3f} "
          f"{r.area_units:7.0f} {r.power_mw:6.1f}mW  {sched}")

print("\nReading: MMT (multicast) = SUMMA all-gather matmul; "
      "SST (systolic) = Cannon ppermute rings; STS/TSS = ring "
      "reduce-scatter — one STT matrix selects both the kernel template "
      "and the collective schedule.")
