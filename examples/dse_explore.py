"""Design-space exploration walkthrough (paper Fig. 6 in miniature).

Enumerates every distinct GEMM dataflow TensorLib can generate for one loop
selection, costs them with the paper's cycle/area/power model, prints the
Pareto frontier with the mesh-level schedule each point maps to on a TPU
pod, and compiles the best point to a validated executable via
``repro.compile.lower``.

    PYTHONPATH=src python examples/dse_explore.py
"""
from repro import compile as rcompile
from repro.core import algebra, dse, plan, stt
from repro.dist.schedules import schedule_from_comm_plan


g = algebra.gemm(512, 512, 512)
# paired sweep: dataflow names repeat across distinct T's, so keep the
# (report, dataflow) association instead of a name lookup
pairs = dse.sweep_with_dataflows(g, selections=[("m", "n", "k")])
print(f"distinct GEMM dataflows (one selection, |T entries| <= 1): "
      f"{len(pairs)}")

df_of = {id(r): df for r, df in pairs}
good = [r for r, _ in pairs if r.normalized_perf >= 0.5]
front = dse.pareto_front(good)
print(f"efficient points: {len(good)}; pareto frontier: {len(front)}\n")

print(f"{'dataflow':12s} {'perf':>6s} {'area':>7s} {'power':>7s}  mesh schedule")
for r in sorted(front, key=lambda r: -r.normalized_perf)[:10]:
    sched = schedule_from_comm_plan(plan.comm_plan_for(df_of[id(r)]))
    print(f"{r.dataflow_name:12s} {r.normalized_perf:6.3f} "
          f"{r.area_units:7.0f} {r.power_mw:6.1f}mW  {sched}")

# compile the frontier winner: plan -> executable (shrunk bounds so the
# python loop-nest oracle used for validation stays fast)
best = min(front, key=lambda r: r.cycles)
df = df_of[id(best)]
small = g.with_bounds(m=16, n=16, k=16)
kern = rcompile.lower(small, stt.apply_stt(small, df.selected, df.T),
                      interpret=True, validate=True)
print(f"\ncompiled frontier winner {df.name}: template={kern.template} "
      f"blocks={kern.blocks} validated={kern.validated}")

print("\nReading: MMT (multicast) = SUMMA all-gather matmul; "
      "SST (systolic) = Cannon ppermute rings; STS/TSS = ring "
      "reduce-scatter — one STT matrix selects both the kernel template "
      "and the collective schedule.")
