"""Design-space exploration walkthrough (paper Fig. 6 in miniature).

``repro.search`` enumerates every distinct GEMM dataflow TensorLib can
generate for one loop selection, costs each with the paper's
cycle/area/power model, and returns the ranked candidates;
``repro.generate(search=...)`` consumes the ranking directly and hands
back the compiled winner — DSE to executable in two calls.

    PYTHONPATH=src python examples/dse_explore.py
"""
import repro
from repro.core import algebra, dse, plan, stt
from repro.dist.schedules import schedule_from_comm_plan


g = algebra.gemm(512, 512, 512)
# paired sweep: dataflow names repeat across distinct T's, so keep the
# (report, dataflow) association instead of a name lookup
pairs = dse.sweep_with_dataflows(g, selections=[("m", "n", "k")])
print(f"distinct GEMM dataflows (one selection, |T entries| <= 1): "
      f"{len(pairs)}")

good = [r for r, _ in pairs if r.normalized_perf >= 0.5]
front = dse.pareto_front(good)
print(f"efficient points: {len(good)}; pareto frontier: {len(front)}\n")

ranked = repro.search(g, top_k=10, selections=[("m", "n", "k")])
print(f"{'dataflow':12s} {'perf':>6s} {'area':>7s} {'power':>7s}  mesh schedule")
for r, df in ranked:
    sched = schedule_from_comm_plan(plan.comm_plan_for(df))
    print(f"{r.dataflow_name:12s} {r.normalized_perf:6.3f} "
          f"{r.area_units:7.0f} {r.power_mw:6.1f}mW  {sched}")

# generate the winner: candidates are lowered best-first at shrunk bounds
# (so the python loop-nest oracle used for validation stays fast); the
# first that validates becomes the accelerator
small = g.with_bounds(m=16, n=16, k=16)
small_ranked = [(r, stt.apply_stt(small, df.selected, df.T))
                for r, df in ranked]
acc = repro.generate(small, search=small_ranked, validate=True)
print(f"\ngenerated search winner {acc.dataflow.name}: "
      f"template={acc.template} blocks={acc.kernel.blocks} "
      f"validated={acc.kernel.validated}")

print("\nReading: MMT (multicast) = SUMMA all-gather matmul; "
      "SST (systolic) = Cannon ppermute rings; STS/TSS = ring "
      "reduce-scatter — one STT matrix selects both the kernel template "
      "and the collective schedule, and repro.generate(...).sharded(mesh) "
      "executes the CommPlan directly.")
