"""End-to-end training driver: a ~100M-param dense LM for a few hundred
steps on the synthetic pipeline, with checkpointing and fault tolerance.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch granite-8b]

The config is a scaled-down (--width/--layers) variant of the chosen arch
family so it trains on this CPU container; on TPU hardware, drop the
overrides and pass a mesh (see repro.launch.train).
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.driver import RunConfig, TrainDriver


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config(args.arch)
    cfg = dataclasses.replace(
        base, name=base.name + "-100m", n_layers=args.layers,
        d_model=args.d_model, n_heads=8, n_kv_heads=4,
        head_dim=args.d_model // 8, d_ff=4 * args.d_model, vocab=8192,
        n_experts=min(base.n_experts, 4) if base.n_experts else 0,
        ssm_state=min(base.ssm_state, 32) if base.ssm_state else 0,
        ssm_head_dim=32, attn_every=2 if base.attn_every else 0,
        n_enc_layers=2 if base.n_enc_layers else 0,
        cross_attn_every=2 if base.cross_attn_every else 0,
        frontend_tokens=32 if base.frontend_tokens else 0,
        swa_window=64 if base.swa_window else None,
        remat=False, sequence_parallel=False, dtype="float32",
    )
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps")

    driver = TrainDriver(
        cfg,
        AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch),
        RunConfig(total_steps=args.steps, ckpt_every=100, log_every=25,
                  ckpt_dir=args.ckpt_dir),
    )
    out = driver.run()
    if not out["metrics"]:
        print(f"nothing to do: checkpoint in {args.ckpt_dir} is already at "
              f"step {args.steps}; pass a fresh --ckpt-dir to retrain")
        return
    print("\nstep   loss     lr")
    for m in out["metrics"]:
        print(f"{m['step']:5d}  {m['loss']:.4f}  {m['lr']:.2e}")
    first, last = out["metrics"][0]["loss"], out["metrics"][-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'no progress'}); "
          f"stragglers flagged: {out['stragglers']}")


if __name__ == "__main__":
    main()
