"""Serve a small model through the continuous-batching server.

    PYTHONPATH=src python examples/serve_lm.py [--arch h2o-danube-1.8b]

Submits a mixed-length wave of requests to ``ContinuousServer`` (slot
engine + paged KV cache underneath), then replays each prompt through
the static-batch ``DecodeEngine`` — the sequential oracle — and checks
the continuous outputs are bit-identical.
"""
import argparse

import numpy as np
import jax

from repro.configs import get_config
from repro.models import init_params, split
from repro.serve import ContinuousServer, DecodeEngine, ServeConfig, SlotEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--max-context", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"serving {cfg.name} ({cfg.family}); "
          f"{cfg.param_count() / 1e6:.2f}M params (reduced config)")
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))

    engine = SlotEngine(params, cfg, capacity=args.capacity,
                        max_context=args.max_context,
                        page_size=args.page_size,
                        serve_cfg=ServeConfig())
    rng = np.random.default_rng(0)
    requests = [(rng.integers(0, cfg.vocab, (s0,)).astype(np.int32), t_new)
                for s0, t_new in [(24, 16), (12, 8), (32, 12), (8, 20),
                                  (16, 16), (24, 8)]]

    with ContinuousServer(engine, prefill_per_step=2) as server:
        futures = [server.submit(p, max_new_tokens=t) for p, t in requests]
        server.drain(timeout=600)
        outputs = [f.result() for f in futures]
        print(f"served {len(requests)} requests in {server.stats['steps']} "
              f"decode steps (mean occupancy "
              f"{server.mean_occupancy():.2f}, decode compiles "
              f"{engine.decode_compiles})")
    for i, out in enumerate(outputs):
        print(f"  req {i} ({len(requests[i][0])} -> {len(out)}): "
              f"{out.tolist()}")

    # oracle: sequential static-batch decode with the same cache budget
    oracle = DecodeEngine(params, cfg)
    for (prompt, t_new), out in zip(requests, outputs):
        want, _ = oracle.generate(prompt[None], max_new_tokens=t_new,
                                  cache_len=args.max_context)
        assert np.array_equal(out, want[0]), "continuous != sequential"
    print("serve OK (continuous outputs bit-identical to sequential decode)")


if __name__ == "__main__":
    main()
