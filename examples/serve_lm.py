"""Serve a small model with batched requests through the decode engine.

    PYTHONPATH=src python examples/serve_lm.py [--arch h2o-danube-1.8b]

Prefills a batch of prompts, then decodes greedily — exercising the same
prefill/decode_step functions the dry-run's serve cells lower.
"""
import argparse

import numpy as np
import jax

from repro.configs import get_config
from repro.models import init_params, split
from repro.serve.engine import DecodeEngine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"serving {cfg.name} ({cfg.family}); "
          f"{cfg.param_count() / 1e6:.2f}M params (reduced config)")
    params, _ = split(init_params(jax.random.PRNGKey(0), cfg))
    engine = DecodeEngine(params, cfg,
                          ServeConfig(max_new_tokens=args.new_tokens))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    frontend = None
    if cfg.family in ("encdec", "vlm"):
        frontend = 0.05 * rng.standard_normal(
            (args.batch, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)

    gen, stats = engine.generate(prompts, frontend=frontend)
    print(f"prefill {stats['prefill_len']} tokens -> generated "
          f"{stats['generated']} per sequence")
    for i, row in enumerate(gen):
        print(f"  seq {i}: {row.tolist()}")
    # determinism check (greedy)
    gen2, _ = engine.generate(prompts, frontend=frontend)
    assert np.array_equal(gen, gen2), "greedy decode must be deterministic"
    print("serve OK (deterministic greedy decode)")


if __name__ == "__main__":
    main()
