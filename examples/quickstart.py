"""Quickstart: the paper's pipeline through the one front door.

1. Describe a tensor algebra (GEMM) as a loop nest.
2. Pick a Space-Time Transformation matrix -> TensorLib classifies each
   tensor's dataflow (paper Table I).
3. ``repro.generate`` turns the classification into a complete
   accelerator: the Pallas kernel template on a chip *and* the collective
   schedule between chips, both selected by the same plan.
4. With a device mesh, the same handle executes multi-chip: the generated
   CommPlan compiles to a shard_map program (SUMMA / Cannon / ring-reduce
   fall out as special cases — nothing is hand-picked).

    PYTHONPATH=src python examples/quickstart.py
    # multi-chip on fake devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import algebra

# 1. the computation: C[m,n] += A[m,k] * B[n,k]
gemm = algebra.gemm(m=256, n=256, k=256)

# 2+3. one call per dataflow: classification -> plan -> executable
for kind in ("identity", "output_stationary", "weight_stationary"):
    acc = repro.generate(gemm, kind, validate=False)
    df = acc.dataflow
    print(f"\nSTT {kind!r} -> dataflow {df.name}")
    for t in df.tensors:
        print(f"  {t.tensor}: {t.cls.value:12s} dp={t.dp} dt={t.dt}")
    print(f"  PE modules: {acc.plan.pe_modules}")
    print(f"  kernel template: {acc.template} "
          f"(VMEM-resident: {acc.plan.kernel.resident_tensor})")
    print(f"  mesh schedule: "
          f"{ {t.tensor: t.kind for t in acc.plan.comm.tensors} }")

# 4. run the generated accelerator (interpret mode on CPU; Mosaic on TPU).
#    Blocks come from the same tile chooser the cost model prices with.
acc = repro.generate(gemm, "output_stationary")
print(f"\ncompiled: template={acc.template} blocks={acc.kernel.blocks} "
      f"stationary={acc.kernel.stationary}")
rng = np.random.default_rng(0)
a = jnp.array(rng.standard_normal((256, 256)), jnp.float32)
b = jnp.array(rng.standard_normal((256, 256)), jnp.float32)
c = acc({"A": a, "B": b})
err = float(jnp.abs(c - a @ b.T).max())
print(f"generated kernel vs oracle: max err {err:.2e}")
assert err < 1e-3

# repeat generation is free: the (bounded, thread-safe) compile cache
# returns the same kernel object
again = repro.generate(gemm, "output_stationary")
info = repro.compile.cache_info()
assert again.kernel is acc.kernel and info["hits"] >= 1
print(f"compile cache: {info}")

# 5. algebra graphs: chain accelerators without HBM round trips.  The
#    gelu epilogue folds into the first GEMM's kernel and the "h" edge is
#    consumed fused, so only x / the weights / the output touch HBM.
graph = repro.AlgebraGraph(
    nodes=(
        repro.GraphNode("up", algebra=algebra.gemm(m=64, n=64, k=64),
                        inputs=("x", "w1"), output="h"),
        repro.GraphNode("act", op="gelu", inputs=("h",), output="ha"),
        repro.GraphNode("down", algebra=algebra.gemm(m=64, n=32, k=64),
                        inputs=("ha", "w2"), output="y"),
    ),
    inputs=("x", "w1", "w2"),
    output="y",
)
gacc = repro.generate(graph, search=3)
grep = gacc.plan.cost_report()
x = jnp.array(rng.standard_normal((64, 64)), jnp.float32)
w1 = jnp.array(rng.standard_normal((64, 64)), jnp.float32)
w2 = jnp.array(rng.standard_normal((32, 64)), jnp.float32)
y = gacc({"x": x, "w1": w1, "w2": w2})
# jit the oracle with the operands as *arguments* (a closed-over constant
# would be folded at trace time on a different arithmetic path)
want = jax.jit(lambda x, w1, w2:
               jax.nn.gelu(x @ w1.T, approximate=True) @ w2.T)(x, w1, w2)
err = float(jnp.abs(y - want).max())
print(f"\nfused gemm-gelu-gemm: fused edges {grep.fused_edges}, "
      f"HBM bytes {grep.hbm_bytes:.0f} vs {grep.hbm_bytes_unfused:.0f} "
      f"unfused ({grep.hbm_ratio:.2f}x), max err {err:.2e}")
assert err == 0.0 and grep.hbm_ratio > 1.0

# the fused chain is not just an accounting story: the whole group runs
# as ONE Pallas megakernel with the intermediate in VMEM scratch.
# Compare the modeled HBM saving with the measured wall clock against
# sequential per-node dispatch (build(merge=False)).
from repro.graph import executor as graph_executor
from repro.tune.measure import measure

assert gacc.group_kernels, "the gemm-gelu-gemm chain should merge"
seq = graph_executor.build(graph, interpret=True, merge=False)
ops = {"x": x, "w1": w1, "w2": w2}
assert bool(jnp.all(gacc(ops) == seq(ops)))     # bit-exact either way
t_merged = measure(gacc, ops, warmup=1, repeats=5).median_s
t_seq = measure(seq, ops, warmup=1, repeats=5).median_s
print(f"merged megakernel {list(gacc.group_kernels)}: "
      f"modeled HBM saving {grep.hbm_ratio:.2f}x, measured "
      f"{t_merged * 1e3:.2f}ms vs sequential {t_seq * 1e3:.2f}ms "
      f"({t_seq / t_merged:.2f}x wall clock)")

# 6. a whole transformer layer as ONE graph: qkv projections, scaled
#    softmax attention, output projection + residual, gelu MLP — eight
#    gemms merging into a single megakernel.  The k/vt edges fuse on
#    consumer *rhs* sides (no materialized transpose), the first
#    residual stream r1 is exported as a *tap* so the closing add reads
#    it from HBM without re-running attention.
from repro.graph import from_model

layer = from_model.transformer_layer_graph(l=64, d=64, dv=64, f=128)
lacc = repro.generate(layer)
lrep = lacc.cost_report()
lops = layer.random_operands(seed=0)
lout = lacc(lops)
assert bool(jnp.all(lout == from_model.layer_oracle(lops)))  # bit parity
lseq = graph_executor.build(layer, interpret=True, merge=False)
assert bool(jnp.all(lout == lseq(lops)))
t_layer = measure(lacc, lops, warmup=1, repeats=5).median_s
t_layer_seq = measure(lseq, lops, warmup=1, repeats=5).median_s
print(f"\ntransformer layer graph: merged {list(lacc.group_kernels)}, "
      f"taps {list(lrep.tapped_edges)}")
print(f"  modeled HBM saving {lrep.hbm_ratio:.2f}x, measured layer "
      f"forward {t_layer * 1e3:.2f}ms vs sequential "
      f"{t_layer_seq * 1e3:.2f}ms ({t_layer_seq / t_layer:.2f}x)")

# multi-chip: the same plan drives the chip mesh when devices allow.  The
# SST dataflow's two ppermute rings + sharded output compile to a Cannon
# schedule — derived from the CommPlan, not picked by name.
if len(jax.devices()) >= 4:
    from repro.dist.engine import square_submesh
    multi = acc.sharded(square_submesh(2))
    c2 = multi({"A": a, "B": b})
    err = float(jnp.abs(c2 - a @ b.T).max())
    print(f"multi-chip (2x2 mesh, strategy="
          f"{multi._program().strategy}): max err {err:.2e}")
    assert err < 1e-2
else:
    print("single device only: skipping the mesh demo "
          "(rerun with XLA_FLAGS=--xla_force_host_platform_device_count=8)")
print("quickstart OK")
