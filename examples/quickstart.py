"""Quickstart: the paper's pipeline end-to-end in 60 lines.

1. Describe a tensor algebra (GEMM) as a loop nest.
2. Pick a Space-Time Transformation matrix -> TensorLib classifies each
   tensor's dataflow (paper Table I).
3. The classification selects hardware: a Pallas kernel template
   (intra-chip) and a collective schedule (inter-chip).
4. ``compile.lower`` turns plan into executable: the shared tile chooser
   picks block sizes, the kernel runs and is checked against the oracle,
   and repeat lowerings hit the compile cache.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro import compile as rcompile
from repro.core import algebra, plan, stt

# 1. the computation: C[m,n] += A[m,k] * B[n,k]
gemm = algebra.gemm(m=256, n=256, k=256)

# 2. dataflow generation for three classic STTs
for kind in ("identity", "output_stationary", "weight_stationary"):
    df = stt.apply_stt(gemm, ("m", "n", "k"), stt.stt_from_name(kind))
    print(f"\nSTT {kind!r} -> dataflow {df.name}")
    for t in df.tensors:
        print(f"  {t.tensor}: {t.cls.value:12s} dp={t.dp} dt={t.dt}")

    # 3. hardware generation (module selection)
    ep = plan.plan_for(df)
    print(f"  PE modules: {ep.pe_modules}")
    print(f"  kernel template: {ep.kernel.template} "
          f"(VMEM-resident: {ep.kernel.resident_tensor})")
    print(f"  mesh schedule: "
          f"{ {t.tensor: t.kind for t in ep.comm.tensors} }")

# 4. compile the generated accelerator and run it (interpret mode on CPU;
#    Mosaic on TPU).  Blocks come from the same tile chooser that the cost
#    model prices with, not a hard-coded default.
df = stt.apply_stt(gemm, ("m", "n", "k"), stt.stt_from_name(
    "output_stationary"))
kern = rcompile.lower(gemm, df, interpret=True)
print(f"\ncompiled: template={kern.template} blocks={kern.blocks} "
      f"stationary={kern.stationary}")
rng = np.random.default_rng(0)
a = jnp.array(rng.standard_normal((256, 256)), jnp.float32)
b = jnp.array(rng.standard_normal((256, 256)), jnp.float32)
c = kern({"A": a, "B": b})
err = float(jnp.abs(c - a @ b.T).max())
print(f"generated kernel vs oracle: max err {err:.2e}")
assert err < 1e-3

# repeat lowering is free: the compile cache returns the same kernel
again = rcompile.lower(gemm, df, interpret=True)
info = rcompile.cache_info()
assert again is kern and info["hits"] >= 1
print(f"compile cache: {info}")
print("quickstart OK")
